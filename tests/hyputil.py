"""hypothesis import shim: use the real library when installed, otherwise
skip the property-based tests while keeping every deterministic test in the
same module runnable (a hard `from hypothesis import ...` used to fail the
whole module at collection time on a clean checkout)."""
import pytest

try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    HAVE_HYPOTHESIS = False

    def given(*args, **kwargs):
        del args, kwargs
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed")(f)

    def settings(*args, **kwargs):
        del args, kwargs
        return lambda f: f

    class _StrategyStub:
        """Accepts any hst.<strategy>(...) call made at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    hst = _StrategyStub()
