"""Local-search subsystem invariants (DESIGN.md §7):

- every 2-opt/Or-opt output is a valid permutation;
- tour length is monotonically non-increasing round by round;
- the Pallas two_opt kernel matches the kernels/ref.py oracle bit-for-bit
  and the use_pallas improve path returns identical tours;
- colony_step with local search still jits and scans;
- MMAS+2opt closes the optimum gap on circle_instance(256) versus plain
  MMAS at an equal iteration count (the subsystem's acceptance bar).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aco, localsearch, strategies, tsp
from repro.kernels import ref
from repro.kernels import two_opt as to_k

KEY = jax.random.PRNGKey(13)

KINDS = [k for k in localsearch.STRATEGIES if k != "none"]


def _tours(n, m, seed=0, nn_k=10):
    inst = tsp.random_instance(n, seed=seed)
    prob = aco.make_problem(inst, nn_k)
    ci = strategies.choice_matrix(jnp.ones((n, n)), prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(jax.random.fold_in(KEY, seed),
                                     prob.dist, ci, m)
    return prob, res


# ----------------------------------------------------------- permutations
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("improvement", ["best", "first"])
def test_outputs_are_valid_permutations(kind, improvement):
    prob, res = _tours(50, 12, seed=1)
    cfg = localsearch.LocalSearchConfig(kind=kind, rounds=15,
                                        improvement=improvement)
    out, lens = localsearch.improve_with_lengths(prob.dist, prob.nn,
                                                 res.tours, cfg)
    assert tsp.is_valid_tour(np.asarray(out))
    # lengths returned must be the true closed-tour lengths
    d = np.asarray(prob.dist)
    t = np.asarray(out)
    for i in range(t.shape[0]):
        np.testing.assert_allclose(
            np.asarray(lens)[i], d[t[i], np.roll(t[i], -1)].sum(), rtol=1e-5)


# ------------------------------------------------------------ monotonicity
@pytest.mark.parametrize("kind", KINDS)
def test_length_monotonically_non_increasing(kind):
    prob, res = _tours(40, 10, seed=2)
    cfg = localsearch.LocalSearchConfig(kind=kind, rounds=1)
    t = res.tours
    prev = np.asarray(res.lengths)
    for _ in range(12):
        t, lens = localsearch.improve_with_lengths(prob.dist, prob.nn, t, cfg)
        lens = np.asarray(lens)
        assert (lens <= prev + 1e-2).all()
        assert tsp.is_valid_tour(np.asarray(t))
        prev = lens


def test_converges_to_optimum_on_circle():
    """On a circle instance 2-opt+Or-opt must untangle any tour fully."""
    inst = tsp.circle_instance(64, seed=3)
    prob = aco.make_problem(inst, 12)
    ci = strategies.choice_matrix(jnp.ones((64, 64)), prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(KEY, prob.dist, ci, 8)
    cfg = localsearch.LocalSearchConfig(kind="2opt_oropt", rounds=60)
    _, lens = localsearch.improve_with_lengths(prob.dist, prob.nn,
                                               res.tours, cfg)
    assert float(np.asarray(lens).max()) <= inst.known_optimum * 1.001


# ------------------------------------------------------------- Pallas kernel
@pytest.mark.parametrize("mode", ["best", "first"])
@pytest.mark.parametrize("m,M", [(1, 7), (5, 480), (16, 1537), (33, 4096)])
def test_two_opt_kernel_matches_ref(mode, m, M):
    k = jax.random.fold_in(KEY, m * 10007 + M)
    ks = jax.random.split(k, 5)
    a1, a2, r1, r2 = (jax.random.uniform(ki, (m, M)) * 100 for ki in ks[:4])
    valid = jax.random.uniform(ks[4], (m, M)) < 0.7
    gv, gi = to_k.two_opt_best(a1, a2, r1, r2, valid, thr=1.0, mode=mode,
                               interpret=True)
    ev, ei = ref.two_opt_best(a1, a2, r1, r2, valid, thr=1.0, mode=mode)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ei))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(ev))


@pytest.mark.parametrize("block_n", [128, 512, 2048])
def test_two_opt_kernel_tile_invariance(block_n):
    k = jax.random.fold_in(KEY, block_n)
    ks = jax.random.split(k, 5)
    a1, a2, r1, r2 = (jax.random.uniform(ki, (9, 3000)) * 50 for ki in ks[:4])
    valid = jax.random.uniform(ks[4], (9, 3000)) < 0.5
    gv, gi = to_k.two_opt_best(a1, a2, r1, r2, valid, block_n=block_n,
                               interpret=True)
    ev, ei = ref.two_opt_best(a1, a2, r1, r2, valid)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(ei))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(ev))


@pytest.mark.parametrize("improvement", ["best", "first"])
def test_pallas_improve_path_identical(improvement):
    prob, res = _tours(48, 8, seed=4)
    mk = lambda p: localsearch.LocalSearchConfig(
        kind="2opt", rounds=20, improvement=improvement, use_pallas=p)
    t0, _ = localsearch.improve_with_lengths(prob.dist, prob.nn, res.tours,
                                             mk(False))
    t1, _ = localsearch.improve_with_lengths(prob.dist, prob.nn, res.tours,
                                             mk(True))
    np.testing.assert_array_equal(np.asarray(t0), np.asarray(t1))


# ------------------------------------------------------------- engine wiring
@pytest.mark.parametrize("variant", ["as", "mmas", "acs"])
@pytest.mark.parametrize("ls_tours", ["all", "iteration_best"])
def test_colony_step_with_ls_jits_and_scans(variant, ls_tours):
    inst = tsp.circle_instance(32, seed=5)
    cfg = aco.ACOConfig(iterations=4, variant=variant, selection="gumbel",
                        local_search="2opt_oropt", ls_tours=ls_tours,
                        ls_rounds=6, ls_every=2)
    prob = aco.make_problem(inst, cfg.nn_k)
    st = aco.init_colony(inst, cfg)
    st, _ = aco.colony_step(prob, st, cfg)          # jitted step
    st_scan, hist = aco.run_scan(prob, st, cfg, 3)  # fused scan driver
    assert hist.shape == (3,)
    assert np.isfinite(float(st_scan.best_len))
    assert tsp.is_valid_tour(np.asarray(st_scan.best_tour))


def test_ls_never_worsens_constructed_tours():
    """Within the colony step, LS output lengths <= construction lengths."""
    prob, res = _tours(60, 20, seed=6)
    cfg = aco.ACOConfig(local_search="2opt", ls_rounds=10)
    out, lens = aco.polish_tours(prob, res.tours, cfg)
    assert (np.asarray(lens) <= np.asarray(res.lengths) + 1e-2).all()
    assert tsp.is_valid_tour(np.asarray(out))


def test_unknown_strategy_rejected():
    prob, res = _tours(16, 2, seed=7)
    cfg = localsearch.LocalSearchConfig(kind="3opt")
    with pytest.raises(ValueError, match="unknown local-search"):
        localsearch.improve(prob.dist, prob.nn, res.tours, cfg)


# ---------------------------------------------------------------- acceptance
def test_mmas_2opt_closes_gap_on_circle256():
    """Acceptance: MMAS+2opt beats plain MMAS on circle(256) at an equal
    iteration count, and lands essentially on the optimum."""
    inst = tsp.circle_instance(256, seed=11)
    iters, m = 20, 64
    base = aco.ACOConfig(iterations=iters, variant="mmas",
                         selection="gumbel", m=m)
    ls = aco.ACOConfig(iterations=iters, variant="mmas", selection="gumbel",
                       m=m, local_search="2opt", ls_tours="iteration_best",
                       ls_rounds=128)
    st_b = aco.run(inst, base)
    st_l = aco.run(inst, ls)
    gap_b = float(st_b.best_len) / inst.known_optimum - 1
    gap_l = float(st_l.best_len) / inst.known_optimum - 1
    assert tsp.is_valid_tour(np.asarray(st_l.best_tour))
    assert gap_l < 0.05, (gap_l, gap_b)
    assert gap_l < gap_b * 0.5, (gap_l, gap_b)
