"""Instance-batched solver tests: padding/masking invariants, exact
batch-composition independence (the subsystem's core guarantee), bucket
scheduling, and supervisor/checkpoint crash recovery."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import aco, pheromone, strategies, tsp
from repro.solver import batch as batch_mod
from repro.solver import engine, service

INSTS = (tsp.random_instance(10, seed=1), tsp.circle_instance(12, seed=2),
         tsp.random_instance(13, seed=3), tsp.circle_instance(16, seed=4))
SEEDS = (5, 6, 7, 8)
BUDGETS = (6, 5, 6, 4)


# ---------------------------------------------------------------- batching
def test_bucket_size_policy():
    assert batch_mod.bucket_size(3) == 16          # min_bucket floor
    assert batch_mod.bucket_size(16) == 16
    assert batch_mod.bucket_size(17) == 32
    assert batch_mod.bucket_size(100) == 128
    assert batch_mod.bucket_size(5, min_bucket=4) == 8
    with pytest.raises(ValueError):
        batch_mod.bucket_size(0)


def test_pad_instance_masking():
    inst = tsp.random_instance(10, seed=0)
    padded = tsp.pad_instance(inst, 16)
    d = padded.distances()
    assert d.shape == (16, 16)
    np.testing.assert_array_equal(d[:10, :10], inst.distances())
    assert np.isinf(d[:10, 10:]).all() and np.isinf(d[10:, :10]).all()
    assert (np.diag(d) == 0).all()
    # same-size padding is the identity
    assert tsp.pad_instance(inst, 10) is inst
    with pytest.raises(ValueError):
        tsp.pad_instance(inst, 8)


def test_padded_problem_eta_and_nn():
    inst = tsp.random_instance(10, seed=0)
    prob = batch_mod.padded_problem(inst, 16, nn_k=8)
    eta = np.asarray(prob.eta)
    assert (eta[:10, 10:] == 0).all() and (eta[10:, :10] == 0).all()
    # real rows list all 8 nearest among real cities first (10 - 1 > 8)
    nn = np.asarray(prob.nn)
    assert (nn[:10] < 10).all()
    assert int(prob.n_actual) == 10


def test_masked_construction_tours_and_lengths():
    inst = tsp.random_instance(13, seed=5)
    n_pad = 16
    prob = batch_mod.padded_problem(inst, n_pad, nn_k=8)
    tau = jnp.ones((n_pad, n_pad))
    ci = strategies.choice_matrix(tau, prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(
        jax.random.PRNGKey(0), prob.dist, ci, 6,
        nn=prob.nn, n_actual=prob.n_actual)
    tours = np.asarray(res.tours)
    assert tsp.is_valid_tour(tours)                       # perm of n_pad
    # real prefix is a permutation of the real cities; tail is fixed order
    assert (np.sort(tours[:, :13], axis=1) == np.arange(13)).all()
    np.testing.assert_array_equal(tours[:, 13:],
                                  np.tile(np.arange(13, 16), (6, 1)))
    # masked lengths equal the numpy closed real-tour lengths
    d = inst.distances()
    for k in range(6):
        t = tours[k, :13]
        np.testing.assert_allclose(
            res.lengths[k], d[t, np.roll(t, -1)].sum(), rtol=1e-5)


# ------------------------------------------------------------------ engine
def test_engine_anchor_exact_when_unpadded():
    """n_actual == n_pad: the mask-aware engine reduces exactly to aco.run."""
    inst = tsp.circle_instance(16, seed=3)
    cfg = aco.ACOConfig(iterations=6, seed=11)
    st_plain = aco.run(inst, cfg)
    states, b = engine.solve_instances([inst], cfg, seeds=[cfg.seed],
                                       n_pad=16)
    row = engine.collect(states, b)[0]
    assert float(st_plain.best_len) == row["best_len"]
    np.testing.assert_array_equal(np.asarray(st_plain.best_tour),
                                  row["best_tour"])


@pytest.mark.parametrize("variant,ls", [
    ("as", "none"), ("mmas", "none"), ("acs", "none"),
    ("as", "2opt"), ("mmas", "2opt_oropt"), ("acs", "2opt"),
])
def test_padding_equivalence_batched_vs_alone(variant, ls):
    """Acceptance: an instance solved inside a padded batch gets exactly the
    best tour length it gets when solved alone with the same seed."""
    cfg = aco.ACOConfig(iterations=max(BUDGETS), variant=variant,
                        selection="gumbel", local_search=ls, ls_rounds=4)
    stb, _ = engine.solve_instances(INSTS, cfg, iterations=BUDGETS,
                                    seeds=SEEDS, n_pad=16)
    batch_lens = np.asarray(stb.best_len)
    batch_tours = np.asarray(stb.best_tour)
    for i, inst in enumerate(INSTS):
        st1, _ = engine.solve_instances(
            [inst], cfg, iterations=[BUDGETS[i]], seeds=[SEEDS[i]], n_pad=16)
        assert float(np.asarray(st1.best_len)[0]) == batch_lens[i], (
            inst.name, variant, ls)
        np.testing.assert_array_equal(np.asarray(st1.best_tour)[0],
                                      batch_tours[i])
        # the result is a valid real-city tour with matching length
        real = batch_tours[i][:inst.n]
        assert tsp.is_valid_tour(real)
        d = inst.distances()
        np.testing.assert_allclose(
            batch_lens[i], d[real, np.roll(real, -1)].sum(), rtol=1e-5)


def test_per_instance_budgets_and_freeze():
    cfg = aco.ACOConfig(iterations=8, selection="gumbel")
    states, _ = engine.solve_instances(INSTS, cfg, iterations=(2, 8, 4, 1),
                                       seeds=SEEDS, n_pad=16)
    np.testing.assert_array_equal(np.asarray(states.iteration), [2, 8, 4, 1])


@pytest.mark.parametrize("strategy", pheromone.STRATEGIES)
def test_batched_vs_solo_all_deposit_strategies(strategy):
    """Every registered deposit strategy is mask-aware inside the batched
    engine: batched == solo bitwise, same as the scatter/reduction paths."""
    cfg = aco.ACOConfig(iterations=4, deposit=strategy, deposit_tile=8,
                        selection="gumbel")
    stb, _ = engine.solve_instances(INSTS[:3], cfg, iterations=[4, 3, 4],
                                    seeds=SEEDS[:3], n_pad=16)
    for i, inst in enumerate(INSTS[:3]):
        st1, _ = engine.solve_instances(
            [inst], cfg, iterations=[[4, 3, 4][i]], seeds=[SEEDS[i]],
            n_pad=16)
        assert float(np.asarray(st1.best_len)[0]) == \
            float(np.asarray(stb.best_len)[i]), (strategy, i)
        np.testing.assert_array_equal(np.asarray(st1.best_tour)[0],
                                      np.asarray(stb.best_tour)[i])


@pytest.mark.parametrize("strategy", [s for s in pheromone.STRATEGIES
                                      if s != "scatter"])
def test_masked_deposit_strategies_match_scatter(strategy):
    """Unit-level mask check, independent of the engine: every strategy's
    masked deposit matrix matches the masked scatter reference (up to float
    associativity) and puts zero mass on phantom rows/cols."""
    inst = tsp.random_instance(13, seed=5)
    prob = batch_mod.padded_problem(inst, 16, nn_k=8)
    ci = strategies.choice_matrix(jnp.ones((16, 16)), prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(
        jax.random.PRNGKey(0), prob.dist, ci, 6,
        nn=prob.nn, n_actual=prob.n_actual)
    w = 1.0 / res.lengths
    ref = np.asarray(pheromone.deposit(16, res.tours, w, "scatter",
                                       n_actual=prob.n_actual))
    d = np.asarray(pheromone.deposit(16, res.tours, w, strategy, tile=8,
                                     n_actual=prob.n_actual))
    np.testing.assert_allclose(d, ref, rtol=1e-5, atol=1e-7)
    assert (d[13:, :] == 0).all() and (d[:, 13:] == 0).all()


@pytest.mark.parametrize("variant", ["as", "mmas", "acs"])
def test_per_instance_hyperparams_exactness(variant):
    """One bucket mixes alpha/beta/rho/q profiles (traced per-slot Hyper
    operands): each instance still reproduces its solo run — same profile,
    same seed — bitwise.  MMAS exercises the rho-dependent tau0 and clip."""
    cfg = aco.ACOConfig(iterations=max(BUDGETS), variant=variant,
                        selection="gumbel")
    profiles = [aco.Hyper.make(cfg),
                aco.Hyper.make(cfg, alpha=2.0, rho=0.3),
                aco.Hyper.make(cfg, beta=3.0, q=2.0),
                aco.Hyper.make(cfg, rho=0.8)]
    stb, _ = engine.solve_instances(INSTS, cfg, iterations=BUDGETS,
                                    seeds=SEEDS, n_pad=16, hypers=profiles)
    for i, inst in enumerate(INSTS):
        st1, _ = engine.solve_instances(
            [inst], cfg, iterations=[BUDGETS[i]], seeds=[SEEDS[i]],
            n_pad=16, hypers=[profiles[i]])
        assert float(np.asarray(st1.best_len)[0]) == \
            float(np.asarray(stb.best_len)[i]), (variant, i)
        np.testing.assert_array_equal(np.asarray(st1.best_tour)[0],
                                      np.asarray(stb.best_tour)[i])


def test_make_batch_rejects_mixed_hyper_presence():
    cfg = aco.ACOConfig()
    with pytest.raises(ValueError, match="all-None or all-set"):
        batch_mod.make_batch(INSTS[:2], 16,
                             hypers=[aco.Hyper.make(cfg), None])


def test_masked_local_search_improves_and_preserves_tail():
    inst = tsp.circle_instance(24, seed=9)
    prob = batch_mod.padded_problem(inst, 32, nn_k=10)
    cfg = aco.ACOConfig(local_search="2opt_oropt", ls_rounds=16)
    tau = jnp.ones((32, 32))
    ci = strategies.choice_matrix(tau, prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(
        jax.random.PRNGKey(1), prob.dist, ci, 8,
        nn=prob.nn, n_actual=prob.n_actual)
    out, lens = aco.polish_tours(prob, res.tours, cfg)
    out = np.asarray(out)
    assert (np.asarray(lens) <= np.asarray(res.lengths) + 1e-3).all()
    assert float(np.asarray(lens).min()) < float(np.asarray(res.lengths).min())
    # phantom tail untouched, real prefix still a permutation
    np.testing.assert_array_equal(out[:, 24:],
                                  np.tile(np.arange(24, 32), (8, 1)))
    assert (np.sort(out[:, :24], axis=1) == np.arange(24)).all()


# ----------------------------------------------------------------- service
def test_service_buckets_schedules_and_stats():
    cfg = aco.ACOConfig(iterations=5, selection="gumbel")
    svc = service.SolverService(cfg, max_batch=2, min_bucket=16)
    sizes = [10, 12, 14, 20, 24, 30]
    ids = [svc.submit(tsp.circle_instance(n, seed=n)) for n in sizes]
    assert svc.pending == 6
    results = svc.run()
    assert svc.pending == 0
    assert [r.request_id for r in results] == ids
    assert {r.bucket for r in results} == {16, 32}
    # 3 requests per bucket, max_batch=2 -> 2 jobs per bucket
    assert svc.stats["batches"] == 4
    assert svc.stats["buckets"] == {"16": 3, "32": 3}
    assert svc.stats["instances_per_s"] > 0
    for r, n in zip(results, sizes):
        assert r.n == n and len(r.best_tour) == n
        assert tsp.is_valid_tour(r.best_tour)
        assert r.gap_pct is not None and r.gap_pct < 100.0
        assert r.iterations == 5


def test_service_rejects_unsupported_configs():
    # mask-aware kernel routes: use_pallas services are supported now
    service.SolverService(aco.ACOConfig(use_pallas=True))
    with pytest.raises(ValueError, match="deposit"):
        service.SolverService(aco.ACOConfig(deposit="nope"))
    # every registered deposit strategy is mask-aware now
    for s in pheromone.STRATEGIES:
        service.SolverService(aco.ACOConfig(deposit=s))


def test_service_checkpoint_crash_recovery(tmp_path, monkeypatch):
    """A crash mid-job restores from the newest checkpoint and yields the
    exact uninterrupted result — including with patience, whose stagnation
    counters are checkpointed next to the ColonyState so chunked runs
    compose exactly."""
    insts = [tsp.circle_instance(n, seed=n) for n in (10, 12, 14)]
    cfg = aco.ACOConfig(iterations=6, selection="gumbel")

    svc_ref = service.SolverService(cfg, max_batch=4, patience=3)
    for i in insts:
        svc_ref.submit(i)
    ref = svc_ref.run()

    real_run_batch = engine.run_batch
    crashes = {"left": 1}

    def flaky(problem, states, budgets, cfg_, max_iters, patience=0,
              since=None, **kw):
        out = real_run_batch(problem, states, budgets, cfg_, max_iters,
                             patience, since, **kw)
        if int(np.asarray(out[0].iteration).max()) >= 4 and crashes["left"]:
            crashes["left"] -= 1
            raise RuntimeError("injected crash after chunk")
        return out

    monkeypatch.setattr(engine, "run_batch", flaky)
    svc = service.SolverService(cfg, max_batch=4, patience=3,
                                checkpoint_dir=str(tmp_path), ckpt_chunk=2)
    for i in insts:
        svc.submit(i)
    got = svc.run()
    assert crashes["left"] == 0, "crash was never injected"
    for r, e in zip(got, ref):
        assert r.best_len == e.best_len
        np.testing.assert_array_equal(r.best_tour, e.best_tour)
        assert r.iterations == e.iterations
