"""Quantised pheromone pipeline tests (DESIGN.md §15, core/quant.py).

Load-bearing contracts:

1. optim/compression int8 round-trip error is bounded by half a
   quantisation step (per-tensor and per-row), and error feedback makes
   repeated accumulation exact in the mean.
2. QuantTau pytree structure is static per config — zero-width leaves for
   unused slots — and fp32 configs keep the raw Array leaf untouched.
3. The fused/sparse kernel tile-dequant epilogues are bitwise equal to
   the ref.py dequantise-then-select oracles, for every mode.
4. Whole quantised colony runs are bitwise identical between the pure
   and Pallas routes, and engine batched == solo on every leaf
   (payload bits and scales included).
5. The route matrix rejects what is genuinely unsupported: quantised x
   per-instance Hyper (every route), islands, city-sharded colonies,
   unknown dtypes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aco, quant, tsp
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.optim.compression import dequantize_int8, quantize_int8
from repro.solver import engine

KEY = jax.random.PRNGKey(11)


# ---------------------------------------------------- optim/compression int8
def test_quantize_int8_per_tensor_roundtrip():
    x = jax.random.normal(KEY, (33, 65)) * 4.0
    q, scale = quantize_int8(x)                    # deterministic nearest
    assert q.dtype == jnp.int8 and scale.shape == ()
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_quantize_int8_per_row_scales():
    """Rows of wildly different magnitude each get their own scale, so the
    relative error stays bounded per row — a per-tensor scale would crush
    the cold rows to zero."""
    k1, k2 = jax.random.split(KEY)
    hot = jax.random.uniform(k1, (4, 64), minval=0.5, maxval=8.0)
    cold = jax.random.uniform(k2, (4, 64), minval=1e-4, maxval=2e-3)
    x = jnp.concatenate([hot, cold], axis=0)
    q, scale = quantize_int8(x, axis=-1)
    assert scale.shape == (8, 1)                   # keepdims per-row
    deq = np.asarray(dequantize_int8(q, scale))
    err = np.abs(deq - np.asarray(x))
    assert (err <= np.asarray(scale) * 0.5 + 1e-9).all()
    # cold rows survive: a per-tensor scale (~8/127) would zero them out
    assert (np.abs(deq[4:]) > 0).any(axis=-1).all()


def test_quantize_int8_stochastic_is_unbiased():
    # row max 1.0 fixes scale = 1/127; 0.31 then sits between int8 steps
    x = jnp.full((1, 256), 0.31).at[0, 0].set(1.0)
    keys = jax.random.split(jax.random.fold_in(KEY, 3), 64)
    deqs = [np.asarray(dequantize_int8(*quantize_int8(x, key=k,
                                                      axis=-1)))[0, 1:]
            for k in keys]
    mean = np.stack(deqs).mean()
    step = 1.0 / 127.0
    assert abs(mean - 0.31) < 0.25 * step          # << half-step bias
    # individual draws actually straddle the value (rounding is random)
    assert min(d.min() for d in deqs) < 0.31 < max(d.max() for d in deqs)


def test_compensated_accumulation_is_exact_in_the_limit():
    """Error feedback (optim/compression invariant): carrying the residual
    across repeated tiny deposits keeps the accumulated dequantised value
    tracking the exact fp32 sum, while the uncompensated store stalls."""
    rows, width, steps, inc = 1, 64, 200, 1e-3
    exact = 0.1 + steps * inc
    plain = quant.quantise(jnp.full((rows, width), 0.1), "int8")
    comp = quant.quantise(jnp.full((rows, width), 0.1), "int8",
                          compensation=True)
    assert comp.err.shape == (rows, width) and plain.err.shape == (rows, 0)
    for _ in range(steps):
        plain = quant.requantise(quant.dequantise(plain) + inc, plain, "int8")
        comp = quant.requantise(quant.dequantise(comp) + inc, comp, "int8")
    got_comp = float(np.asarray(quant.dequantise(comp) + comp.err).mean())
    got_plain = float(np.asarray(quant.dequantise(plain)).mean())
    assert abs(got_comp - exact) < 1e-5            # q*scale + err is exact
    assert abs(got_comp - exact) < abs(got_plain - exact)


# ----------------------------------------------------------- QuantTau pytree
def test_quant_tau_leaf_structure_per_dtype():
    x = jax.random.uniform(KEY, (16, 16)) + 0.1
    t8 = quant.quantise(x, "int8")
    assert t8.q.dtype == jnp.int8 and t8.scale.shape == (16, 1)
    assert t8.err.shape == (16, 0)                 # compensation off
    tb = quant.quantise(x, "bf16")
    assert tb.q.dtype == jnp.bfloat16
    assert tb.scale.shape == (16, 0) and tb.err.shape == (16, 0)
    # bf16 needs no scale: dequant is exactly the f32 cast
    np.testing.assert_array_equal(np.asarray(quant.dequantise(tb)),
                                  np.asarray(x.astype(jnp.bfloat16)
                                              .astype(jnp.float32)))
    # always 3 leaves -> static pytree structure per config
    assert len(jax.tree.leaves(t8)) == len(jax.tree.leaves(tb)) == 3


def test_quantise_zero_width_store():
    """sparse_overflow=0 pages quantise without reducing over an empty
    axis, keeping the same leaf dtypes as the non-empty case."""
    z = jnp.zeros((8, 0), jnp.float32)
    t8 = quant.quantise(z, "int8")
    assert t8.q.dtype == jnp.int8 and t8.q.shape == (8, 0)
    assert t8.scale.shape == (8, 1)
    tb = quant.quantise(z, "bf16")
    assert tb.q.dtype == jnp.bfloat16 and tb.scale.shape == (8, 0)


def test_make_tau_fp32_is_raw_array_and_nbytes_ratios():
    n = 64
    x = jax.random.uniform(KEY, (n, n), minval=0.05, maxval=2.0)
    cfg32 = aco.ACOConfig()
    raw = aco.make_tau(x, cfg32)
    assert raw is x                                # untouched leaf: bitwise
    f32 = quant.tau_nbytes(raw)
    bf = quant.tau_nbytes(aco.make_tau(x, aco.ACOConfig(tau_dtype="bf16")))
    i8 = quant.tau_nbytes(aco.make_tau(x, aco.ACOConfig(tau_dtype="int8")))
    assert f32 == n * n * 4
    assert f32 / bf == 2.0                         # exact: no scale leaf
    assert f32 / i8 >= 3.0                         # payload + (n,1) scales
    with pytest.raises(ValueError, match="tau_dtype"):
        quant.validate_tau_dtype("fp8")
    with pytest.raises(ValueError, match="tau_round"):
        quant.validate_tau_dtype("int8", "banker")


def test_round_key_discipline():
    k = jax.random.PRNGKey(0)
    assert quant.round_key("stochastic", k) is k
    assert quant.round_key("nearest", k) is None


# ------------------------------------------------- kernel dequant epilogues
def _quant_fused_case(tau_dtype, mode, m=9, n=130, alpha=1.0, beta=2.0,
                      n_actual=None, seed=0):
    from repro.kernels import fused_select as fs_k
    k = jax.random.fold_in(KEY, seed * 7919 + m * 31 + n)
    tau = jax.random.uniform(k, (n, n), minval=0.05, maxval=2.0)
    eta = jax.random.uniform(jax.random.fold_in(k, 1), (n, n)) + 0.1
    hi = n if n_actual is None else int(n_actual)
    if n_actual is not None:
        eta = eta.at[:, hi:].set(0.0).at[hi:, :].set(0.0)
    cur = jax.random.randint(jax.random.fold_in(k, 2), (m,), 0, hi)
    vis = jax.random.uniform(jax.random.fold_in(k, 3), (m, n)) < 0.5
    vis = vis.at[:, 0].set(False)
    rand = jax.random.uniform(jax.random.fold_in(k, 4), (m, n),
                              minval=1e-6, maxval=1.0)
    na = None if n_actual is None else jnp.int32(n_actual)
    t = quant.quantise(tau, tau_dtype)
    scale = t.scale if tau_dtype == "int8" else None
    got = fs_k.fused_select(t.q, eta, cur, vis, rand, alpha, beta, na, mode,
                            tau_scale=scale, interpret=True)
    exp = ref.fused_select_quant(t.q, scale, eta, cur, vis.astype(jnp.int8),
                                 rand, alpha, beta, na, mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@pytest.mark.parametrize("mode", ["iroulette", "gumbel", "greedy"])
@pytest.mark.parametrize("tau_dtype", ["bf16", "int8"])
def test_fused_select_quant_matches_oracle(tau_dtype, mode):
    """The kernel's per-tile dequant epilogue (one-hot gather of payload,
    then multiply by the gathered per-row scale) must be bitwise the
    oracle's full-dequantise-then-select — identical f32 multiply
    operands, so gather/dequant order cannot matter."""
    _quant_fused_case(tau_dtype, mode)
    _quant_fused_case(tau_dtype, mode, n=259, n_actual=197)


@pytest.mark.parametrize("mode", ["iroulette", "gumbel", "greedy"])
@pytest.mark.parametrize("tau_dtype", ["bf16", "int8"])
def test_sparse_select_quant_matches_oracle(tau_dtype, mode):
    m, n, kk = 13, 100, 9
    ks = jax.random.split(jax.random.fold_in(KEY, hash(mode) % 1000), 5)
    tau = jax.random.uniform(ks[0], (m, kk), minval=0.05, maxval=2.0)
    eta = jax.random.uniform(ks[1], (m, kk)) + 0.1
    cand = jax.random.randint(ks[2], (m, kk), 0, n)
    cand = jnp.where(jax.random.bernoulli(ks[3], 0.1, (m, kk)), -1, cand)
    visited = jax.random.bernoulli(ks[3], 0.4, (m, n))
    rand = jax.random.uniform(ks[4], (m, n), jnp.float32, 1e-6, 1.0)
    t = quant.quantise(tau, tau_dtype)
    if tau_dtype == "int8":
        rows, scale = t.q, jnp.broadcast_to(t.scale, (m, kk))
    else:
        rows, scale = t.q, None
    pos, have = kops.sparse_select(rows, eta, cand, visited, rand,
                                   1.0, 2.0, mode, tau_scale=scale)
    rpos, rhave = ref.sparse_select_quant(rows, scale, eta, cand, visited,
                                          rand, 1.0, 2.0, mode)
    np.testing.assert_array_equal(np.asarray(have), np.asarray(rhave))
    live = np.asarray(have).astype(bool)
    np.testing.assert_array_equal(np.asarray(pos)[live],
                                  np.asarray(rpos)[live])


# ------------------------------------------------------- whole colony runs
def _state_bits(st):
    out = {}
    for name, leaf in zip(st._fields, st):
        for sub in jax.tree.leaves(leaf):
            a = np.asarray(sub)
            out[f"{name}:{a.dtype}"] = a.view(np.uint8).sum() if a.size \
                else 0
    return out


@pytest.mark.parametrize("variant,full_bitwise", [
    ("as", False),     # m ants deposit: summation order differs by design
    ("mmas", True),    # single-tour deposit: every cell gets <= 1 deposit
    ("acs", False),    # shared post-deposit math fuses differently (ulp)
])
@pytest.mark.parametrize("tau_dtype", ["bf16", "int8"])
def test_quantised_pure_matches_pallas(variant, full_bitwise, tau_dtype):
    """The fused tile-dequant route against the pure route through whole
    quantised runs: tours / best lengths / keys bitwise always; the
    resident payload+scales bitwise where the fp32 deposit is single-hit
    per cell (MMAS — the same contract the fp32 routes carry), ulp-close
    on the dequantised store otherwise."""
    inst = tsp.random_instance(24, seed=9)
    cfg = aco.ACOConfig(iterations=5, variant=variant, selection="gumbel",
                        tau_dtype=tau_dtype)
    pure = aco.run(inst, cfg)
    pal = aco.run(inst, dataclasses.replace(cfg, use_pallas=True))
    assert isinstance(pure.tau, quant.QuantTau)
    if full_bitwise:
        np.testing.assert_array_equal(np.asarray(pure.tau.q),
                                      np.asarray(pal.tau.q))
        np.testing.assert_array_equal(np.asarray(pure.tau.scale),
                                      np.asarray(pal.tau.scale))
    else:
        np.testing.assert_allclose(
            np.asarray(quant.dequantise(pure.tau)),
            np.asarray(quant.dequantise(pal.tau)), rtol=1e-4, atol=1e-6)
    assert float(pure.best_len) == float(pal.best_len)
    np.testing.assert_array_equal(np.asarray(pure.best_tour),
                                  np.asarray(pal.best_tour))
    np.testing.assert_array_equal(np.asarray(pure.key), np.asarray(pal.key))


def test_quantised_run_produces_valid_tours_nearest_and_compensated():
    inst = tsp.circle_instance(20, seed=2)
    for kw in ({"tau_round": "nearest"}, {"tau_compensation": True}):
        cfg = aco.ACOConfig(iterations=4, variant="mmas", tau_dtype="int8",
                            selection="gumbel", **kw)
        st = aco.run(inst, cfg)
        assert tsp.is_valid_tour(np.asarray(st.best_tour))
        assert np.isfinite(float(st.best_len))
        want = (20, 20) if kw.get("tau_compensation") else (20, 0)
        assert st.tau.err.shape == want


def test_fp32_trajectory_untouched_by_quant_plumbing():
    """tau_dtype='fp32' must keep the raw Array leaf and the exact 2-way
    key split — bitwise the pre-quantisation trajectory."""
    inst = tsp.random_instance(16, seed=3)
    st = aco.run(inst, aco.ACOConfig(iterations=3))
    assert not isinstance(st.tau, quant.QuantTau)
    assert st.tau.dtype == jnp.float32


def test_sparse_quantised_pure_matches_pallas():
    from repro.sparse import aco as sa
    inst = tsp.random_instance(32, seed=4)
    for tau_dtype in ("bf16", "int8"):
        cfg = aco.ACOConfig(iterations=4, variant="mmas", sparse=True,
                            sparse_k=8, selection="iroulette",
                            tau_dtype=tau_dtype)
        pure = sa.run_sparse(inst, cfg)
        pal = sa.run_sparse(inst, dataclasses.replace(cfg, use_pallas=True))
        assert isinstance(pure.tau, quant.QuantTau)
        np.testing.assert_array_equal(np.asarray(pure.tau.q),
                                      np.asarray(pal.tau.q))
        np.testing.assert_array_equal(np.asarray(pure.ovf_tau.q),
                                      np.asarray(pal.ovf_tau.q))
        assert float(pure.best_len) == float(pal.best_len)
        assert tsp.is_valid_tour(np.asarray(pure.best_tour))


def test_sparse_quantised_zero_overflow():
    from repro.sparse import aco as sa
    inst = tsp.circle_instance(24, seed=5)
    cfg = aco.ACOConfig(iterations=3, sparse=True, sparse_k=8,
                        sparse_overflow=0, tau_dtype="int8",
                        selection="gumbel")
    st = sa.run_sparse(inst, cfg)
    assert st.ovf_tau.q.shape[-1] == 0
    assert tsp.is_valid_tour(np.asarray(st.best_tour))


# --------------------------------------------------------- engine == solo
def test_engine_batched_matches_solo_bitwise_int8():
    """Batched quantised slots must be bitwise the solo runs on every
    leaf — payload bits and per-row scales included (slot stacking /
    surgery never mixes quantised state across slots)."""
    insts = [tsp.random_instance(n, seed=n) for n in (10, 13, 12)]
    cfg = aco.ACOConfig(iterations=5, variant="mmas", selection="gumbel",
                        tau_dtype="int8")
    batched, _ = engine.solve_instances(insts, cfg, iterations=[5, 5, 5],
                                        seeds=[1, 2, 3], n_pad=16)
    for i, inst in enumerate(insts):
        solo, _ = engine.solve_instances([inst], cfg, iterations=[5],
                                         seeds=[1 + i], n_pad=16)
        np.testing.assert_array_equal(np.asarray(batched.tau.q[i]),
                                      np.asarray(solo.tau.q[0]))
        np.testing.assert_array_equal(np.asarray(batched.tau.scale[i]),
                                      np.asarray(solo.tau.scale[0]))
        assert float(batched.best_len[i]) == float(solo.best_len[0])
        np.testing.assert_array_equal(np.asarray(batched.best_tour[i]),
                                      np.asarray(solo.best_tour[0]))


# ------------------------------------------------------------ route matrix
def test_route_matrix_rejects_quantised_hyper():
    for dt in ("int8", "bf16"):
        with pytest.raises(kops.UnsupportedKernelRoute, match="quantised"):
            kops.check_kernel_route(hyper=True, tau_dtype=dt)
    # quantised alone stays accepted on the kernel and sparse routes
    kops.check_kernel_route(tau_dtype="int8")
    kops.check_kernel_route(sparse=True, tau_dtype="bf16",
                            selection="gumbel")
    with pytest.raises(kops.UnsupportedKernelRoute, match="tau_dtype"):
        kops.check_kernel_route(tau_dtype="fp16")


def test_colony_step_rejects_quantised_hyper_on_pure_route():
    inst = tsp.random_instance(10, seed=0)
    cfg = aco.ACOConfig(iterations=1, tau_dtype="int8")
    prob = aco.make_problem(inst, cfg.nn_k)
    prob = prob._replace(hyper=aco.Hyper.make(cfg, alpha=2.0))
    st = aco.init_colony(inst, cfg)
    with pytest.raises(kops.UnsupportedKernelRoute, match="quantised"):
        aco.colony_step(prob, st, cfg)


def test_streaming_rejects_quantised_hyper_eagerly():
    from repro.solver import streaming
    cfg = aco.ACOConfig(iterations=2, tau_dtype="int8")
    streaming.StreamingSolverService(cfg)          # quantised alone: fine
    with pytest.raises(kops.UnsupportedKernelRoute, match="Hyper"):
        streaming.StreamingSolverService(cfg, per_instance_hyper=True)


def test_islands_and_city_sharded_reject_quantised():
    from repro.core import islands
    inst = tsp.circle_instance(12, seed=0)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    icfg = islands.IslandConfig(
        aco=aco.ACOConfig(iterations=1, tau_dtype="bf16"), rounds=1)
    with pytest.raises(kops.UnsupportedKernelRoute, match="island"):
        islands.run_islands(inst, icfg, mesh)
    mmesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("model",))
    with pytest.raises(kops.UnsupportedKernelRoute, match="sharded"):
        islands.run_sharded_colony(
            inst, aco.ACOConfig(iterations=1, tau_dtype="int8"), mmesh)
