"""AOT program cache tests (solver/programs.py, DESIGN.md §16).

The load-bearing claims: (1) a warmed signature dispatches the AOT
executable and the result is bitwise the jit path's; (2) neighbour-bucket
routing — padding an unwarmed native bucket into the nearest larger warmed
one — is bitwise exact for counter-mode configs across AS/MMAS/ACS,
quantised and sparse routes, and is *refused* for any config whose
numerics depend on the bucket width; (3) the persistent XLA cache and the
hit/miss/warmup counters are actually wired.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import aco, tsp
from repro.kernels.ops import UnsupportedKernelRoute
from repro.solver import batch as batch_mod
from repro.solver import engine, programs, service, streaming

# The AOT warm/dispatch tests compile dozens of distinct engine programs.
# Run in the long-lived suite process, that much extra JIT code has
# destabilised *later, unrelated* XLA CPU compiles (deterministic
# segfault in test_system's construct_tours compile — reproduced 3/3
# with these tests in-process, 0/2 without).  So the compile-heavy tests
# are marked `_HEAVY` and executed in their own interpreter by
# test_aot_service_suite_isolated below (the test_distributed.py
# subprocess idiom); set REPRO_PROGRAMS_HEAVY=1 to run them directly.
_HEAVY = os.environ.get("REPRO_PROGRAMS_HEAVY") == "1"
heavy = pytest.mark.skipif(
    not _HEAVY, reason="runs via test_aot_service_suite_isolated")


def _counter_cfg(**kw):
    """Neighbour-routable base config: pinned ants + width-invariant
    counter draws, no local search."""
    base = dict(iterations=4, m=4, draw_mode="counter",
                local_search="none", seed=0)
    base.update(kw)
    return aco.ACOConfig(**base)


# Keep every ProgramCache (and so every AOT LoadedExecutable) alive for
# the whole process — a service holds its cache until exit, and tests
# should exercise that lifetime, not a create-and-GC churn production
# never does.
_LIVE_CACHES: list = []


def _cache(**kw) -> programs.ProgramCache:
    pc = programs.ProgramCache(**kw)
    _LIVE_CACHES.append(pc)
    return pc


# ------------------------------------------------------------ bucket ladder
def test_bucket_ladder_enumeration():
    assert batch_mod.bucket_ladder(10, 100) == [16, 32, 64, 128]
    assert batch_mod.bucket_ladder(20, 20) == [32]
    assert batch_mod.bucket_ladder(3, 17, min_bucket=4) == [4, 8, 16, 32]
    with pytest.raises(ValueError):
        batch_mod.bucket_ladder(10, 9)


def test_bucket_ladder_covers_bucket_size():
    """Every instance size in range lands in a ladder rung."""
    ladder = batch_mod.bucket_ladder(5, 70)
    for n in range(5, 71):
        assert batch_mod.bucket_size(n) in ladder


# ------------------------------------------------------ keying / canonical
def test_effective_max_iters_canonicalisation():
    pc = programs.ProgramCache(iters_cap=8)
    assert pc.effective_max_iters(3) == 8    # shared warmed loop bound
    assert pc.effective_max_iters(8) == 8
    assert pc.effective_max_iters(9) == 9    # over the cap: exact budget
    assert programs.ProgramCache().effective_max_iters(5) == 5


def test_signature_reads_operand_shapes():
    cfg = _counter_cfg()
    insts = [tsp.circle_instance(10, seed=0)] * 2
    b = batch_mod.make_batch(insts, 16, cfg.nn_k)
    states = engine.init_states(insts, cfg, [0, 1], 16)
    budgets = jnp.zeros((2,), jnp.int32)
    key = programs.ProgramCache.signature(
        b.problem, states, budgets, cfg, 4, 0, False, "dense", "EUC_2D")
    assert key.n_pad == 16 and key.batch == 2
    assert key.cfg == cfg and not key.hyper
    assert key.mesh == programs.MESH_NONE


def test_mesh_label():
    assert programs.mesh_label(None) == "-"


# --------------------------------------------------------- rejection matrix
@pytest.mark.parametrize("cfg,why", [
    (aco.ACOConfig(), "cfg.m"),                               # m follows n_pad
    (_counter_cfg(draw_mode="packed"), "draw_mode"),
    (_counter_cfg(local_search="2opt"), "local search"),
    (_counter_cfg(construction="nn_list"), "nn_list"),
    (_counter_cfg(sparse=True, sparse_k=8, construction="partial"),
     "Partial-ACO"),
    (_counter_cfg(tau_dtype="int8", tau_round="stochastic"), "tau_round"),
])
def test_neighbour_route_rejections(cfg, why):
    with pytest.raises(UnsupportedKernelRoute, match=why):
        programs.check_neighbour_route(cfg)
    assert not programs.neighbour_supported(cfg)


@pytest.mark.parametrize("cfg", [
    _counter_cfg(),
    _counter_cfg(variant="acs"),
    _counter_cfg(tau_dtype="int8", tau_round="nearest"),
    _counter_cfg(sparse=True, sparse_k=8),
])
def test_neighbour_route_accepted(cfg):
    programs.check_neighbour_route(cfg)     # must not raise
    assert programs.neighbour_supported(cfg)


def test_route_bucket_policy():
    pc = _cache()
    pc._warmed_buckets[("dense", "-")] = {32, 64}
    ok = _counter_cfg()
    bad = aco.ACOConfig()                    # m=None: not width-invariant
    assert pc.route_bucket(32, ok) == 32     # native warmed: stay
    assert pc.route_bucket(16, ok) == 32     # nearest larger warmed
    assert pc.route_bucket(16, bad) == 16    # unsupported cfg: never route
    assert pc.route_bucket(128, ok) == 128   # nothing larger: native


# ---------------------------------------------------- warm / AOT dispatch
@heavy
def test_warm_hit_is_bitwise_jit_path():
    """A warmed drain service must return bitwise what the plain service
    returns, with every job an AOT hit and zero misses."""
    cfg = aco.ACOConfig(iterations=4, variant="mmas", seed=0)
    insts = [tsp.random_instance(10, seed=1), tsp.circle_instance(12, seed=2),
             tsp.random_instance(14, seed=3)]

    plain = service.SolverService(cfg, max_batch=2)
    for k, inst in enumerate(insts):
        plain.submit(inst, seed=50 + k)
    want = plain.run()

    pc = _cache()
    svc = service.SolverService(cfg, max_batch=2, programs=pc)
    summary = svc.warm_programs(10, 14)
    assert set(summary["buckets"]) == {"16"} and not summary["errors"]
    for k, inst in enumerate(insts):
        svc.submit(inst, seed=50 + k)
    got = svc.run()

    st = svc.stats["programs"]
    assert st["hits"] == 2 and st["misses"] == 0       # 2 jobs of max_batch=2
    assert st["warmup_programs"] == 1 and st["warmup_compile_s"] > 0
    assert pc.warmed_buckets("dense") == (16,)
    for a, b in zip(want, got):
        assert a.best_len == b.best_len
        np.testing.assert_array_equal(a.best_tour, b.best_tour)


@heavy
def test_drain_phantom_padding_is_exact():
    """One real request padded with budget-0 phantom slots to max_batch
    must surface exactly the solo result, and only that result."""
    cfg = aco.ACOConfig(iterations=4, seed=0)
    inst = tsp.random_instance(11, seed=7)

    plain = service.SolverService(cfg, max_batch=4)
    plain.submit(inst, seed=9)
    want = plain.run()

    pc = _cache()
    svc = service.SolverService(cfg, max_batch=4, programs=pc)
    svc.warm_programs(11, 11)
    svc.submit(inst, seed=9)
    got = svc.run()

    assert len(got) == len(want) == 1
    assert svc.stats["programs"]["hits"] == 1
    assert got[0].best_len == want[0].best_len
    np.testing.assert_array_equal(got[0].best_tour, want[0].best_tour)
    assert tsp.is_valid_tour(got[0].best_tour)


@heavy
def test_background_warm_and_miss_fallback():
    """Before a background warm lands, calls miss and take the jit path;
    wait() joins the thread and subsequent calls hit."""
    cfg = aco.ACOConfig(iterations=3, seed=0)
    inst = tsp.random_instance(10, seed=4)

    pc = _cache()
    svc = service.SolverService(cfg, max_batch=2, programs=pc)
    t = svc.warm_programs(10, 10, background=True)
    assert t is not None
    pc.wait()
    assert pc.warmed_buckets("dense") == (16,)

    svc.submit(inst, seed=3)
    got = svc.run()
    assert svc.stats["programs"]["hits"] == 1
    assert svc.stats["programs"]["misses"] == 0

    # An unwarmed signature (different bucket) misses but still solves.
    svc.submit(tsp.random_instance(20, seed=5), seed=6)
    got2 = svc.run()
    st = svc.stats["programs"]
    assert st["misses"] == 1
    assert st["missed_signatures"][0]["bucket"] == 32
    assert np.isfinite(got[0].best_len) and np.isfinite(got2[0].best_len)


# ------------------------------------------------- neighbour-bucket routing
@pytest.mark.parametrize("variant", ["as", "mmas", "acs"])
@heavy
def test_neighbour_bucket_bitwise_exact_variants(variant):
    """n=12 (native bucket 16) routed into a warmed-only bucket 32 must be
    bitwise the native-bucket run, for every pheromone variant."""
    cfg = _counter_cfg(variant=variant, iterations=5)
    inst = tsp.random_instance(12, seed=31)

    plain = service.SolverService(cfg, max_batch=2)
    plain.submit(inst, seed=8)
    want = plain.run()

    pc = _cache()
    svc = service.SolverService(cfg, max_batch=2, programs=pc)
    svc.warm_programs(20, 20)                 # ladder = [32] only
    assert pc.warmed_buckets("dense") == (32,)
    assert svc._route_bucket(inst.n) == 32    # 16 is cold -> neighbour
    svc.submit(inst, seed=8)
    got = svc.run()

    assert svc.stats["programs"]["hits"] == 1
    assert svc.stats["programs"]["misses"] == 0
    assert got[0].best_len == want[0].best_len
    np.testing.assert_array_equal(got[0].best_tour, want[0].best_tour)


@heavy
def test_neighbour_bucket_bitwise_exact_quantised():
    cfg = _counter_cfg(variant="mmas", iterations=4,
                       tau_dtype="int8", tau_round="nearest")
    inst = tsp.random_instance(12, seed=13)

    plain = service.SolverService(cfg, max_batch=2)
    plain.submit(inst, seed=2)
    want = plain.run()

    pc = _cache()
    svc = service.SolverService(cfg, max_batch=2, programs=pc)
    svc.warm_programs(20, 20)
    svc.submit(inst, seed=2)
    got = svc.run()
    assert svc.stats["programs"]["hits"] == 1
    assert got[0].best_len == want[0].best_len
    np.testing.assert_array_equal(got[0].best_tour, want[0].best_tour)


@heavy
def test_neighbour_bucket_bitwise_exact_sparse():
    cfg = _counter_cfg(variant="mmas", iterations=4, sparse=True,
                       sparse_k=8)
    inst = tsp.random_instance(12, seed=17)

    plain = service.SolverService(cfg, max_batch=2)
    plain.submit(inst, seed=5)
    want = plain.run()

    pc = _cache()
    svc = service.SolverService(cfg, max_batch=2, programs=pc)
    svc.warm_programs(20, 20)
    assert pc.warmed_buckets("sparse") == (32,)
    svc.submit(inst, seed=5)
    got = svc.run()
    assert svc.stats["programs"]["hits"] == 1
    assert got[0].best_len == want[0].best_len
    np.testing.assert_array_equal(got[0].best_tour, want[0].best_tour)


@heavy
def test_packed_draw_mode_never_neighbour_routes():
    """The default packed draws are width-dependent: an attached cache
    must keep the native bucket (compile-on-demand) rather than route."""
    cfg = aco.ACOConfig(iterations=3, seed=0)      # packed, m=None
    pc = _cache()
    svc = service.SolverService(cfg, max_batch=2, programs=pc)
    svc.warm_programs(20, 20)                      # warmed: {32}
    assert svc._route_bucket(12) == 16             # refused, stays native


# ----------------------------------------------------------- streaming svc
@heavy
def test_streaming_warmed_hits_and_bucket_stamp():
    """Streaming: warmed chunks dispatch AOT (hits, zero misses), results
    bitwise the plain pool's; the request bucket is stamped at submit."""
    cfg = aco.ACOConfig(iterations=4, seed=0, selection="gumbel")
    insts = [tsp.random_instance(10, seed=1), tsp.circle_instance(12, seed=2)]

    plain = streaming.StreamingSolverService(cfg, max_batch=2, chunk=2)
    for k, inst in enumerate(insts):
        plain.submit(inst, iterations=4, seed=40 + k)
    want = {r.request_id: r for r in plain.run_until_drained()}

    pc = _cache()
    svc = streaming.StreamingSolverService(cfg, max_batch=2, chunk=2,
                                           programs=pc)
    svc.warm_programs(10, 12)
    for k, inst in enumerate(insts):
        svc.submit(inst, iterations=4, seed=40 + k)
    got = {r.request_id: r for r in svc.run_until_drained()}

    st = svc.stats["programs"]
    assert st["hits"] > 0 and st["misses"] == 0
    for k in want:
        assert got[k].best_len == want[k].best_len
        np.testing.assert_array_equal(got[k].best_tour, want[k].best_tour)


@heavy
def test_streaming_neighbour_route_stamped_at_submit():
    """A neighbour-routed streaming request records its routed bucket on
    the request at submit time and solves bitwise-identically."""
    cfg = _counter_cfg(iterations=4)
    inst = tsp.random_instance(12, seed=23)

    plain = streaming.StreamingSolverService(cfg, max_batch=2, chunk=2)
    plain.submit(inst, iterations=4, seed=6)
    want = plain.run_until_drained()

    pc = _cache()
    svc = streaming.StreamingSolverService(cfg, max_batch=2, chunk=2,
                                           programs=pc)
    svc.warm_programs(20, 20)                 # warmed: {32}
    svc.submit(inst, iterations=4, seed=6)
    assert svc._waiting[0].bucket == 32       # stamped once, at submit
    got = svc.run_until_drained()

    assert svc.stats["programs"]["hits"] > 0
    assert got[0].best_len == want[0].best_len
    np.testing.assert_array_equal(got[0].best_tour, want[0].best_tour)


# ---------------------------------------------------- counter-mode draws
@heavy
def test_counter_draw_mode_is_width_invariant():
    """The exactness basis itself: the same instance solved at n_pad 16
    and 32 under counter draws yields bitwise the same trajectory."""
    cfg = _counter_cfg(iterations=3)
    inst = tsp.random_instance(10, seed=11)
    outs = []
    for n_pad in (16, 32):
        st, _ = engine.solve_instances([inst], cfg, iterations=[3],
                                       seeds=[9], n_pad=n_pad)
        outs.append((float(np.asarray(st.best_len)[0]),
                     np.asarray(st.best_tour)[0][:inst.n]))
    assert outs[0][0] == outs[1][0]
    np.testing.assert_array_equal(outs[0][1], outs[1][1])


@heavy
def test_packed_draw_mode_is_width_dependent():
    """Sanity check that the gate is load-bearing: packed draws really do
    change with the padded width (if this ever starts passing, the
    rejection matrix can be relaxed)."""
    cfg = aco.ACOConfig(iterations=3, m=4, seed=0)   # packed
    inst = tsp.random_instance(10, seed=11)
    diverged = False
    for seed in range(6):        # any one divergence proves dependence
        tours = []
        for n_pad in (16, 32):
            st, _ = engine.solve_instances([inst], cfg, iterations=[3],
                                           seeds=[seed], n_pad=n_pad)
            tours.append(np.asarray(st.best_tour)[0][:inst.n])
        if not np.array_equal(tours[0], tours[1]):
            diverged = True
            break
    assert diverged


# ------------------------------------------------------- persistent cache
def test_persistent_cache_config_roundtrip(tmp_path):
    """enable_persistent_cache points JAX at the directory and zeroes the
    size/time admission gates (restored afterwards — process-global)."""
    old_dir = jax.config.jax_compilation_cache_dir
    old_secs = jax.config.jax_persistent_cache_min_compile_time_secs
    old_bytes = jax.config.jax_persistent_cache_min_entry_size_bytes
    d = str(tmp_path / "xla")
    try:
        got = programs.enable_persistent_cache(d)
        assert got == os.path.abspath(d) and os.path.isdir(d)
        assert jax.config.jax_compilation_cache_dir == got
        assert jax.config.jax_persistent_cache_min_compile_time_secs == 0
        assert jax.config.jax_persistent_cache_min_entry_size_bytes == -1
    finally:
        jax.config.update("jax_compilation_cache_dir", old_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          old_secs)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes",
                          old_bytes)


def test_persistent_cache_populates_and_reuses(tmp_path):
    """The executable cache must be populated by a fresh process that
    enables it before its first compile, and a second process over the
    same directory must reuse it (entry count stable, not re-written).
    Subprocesses because the persistent-cache singleton binds at the
    process's first compile — exactly the serve-time usage."""
    import subprocess
    import sys
    d = str(tmp_path / "xla")
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    code = (
        "import sys, jax, jax.numpy as jnp\n"
        "from repro.solver import programs\n"
        "programs.enable_persistent_cache(sys.argv[1])\n"
        "jax.jit(lambda x: jnp.cumsum(x * 3.0) + 1.0)"
        "(jnp.arange(64, dtype=jnp.float32)).block_until_ready()\n"
        "print(programs.persistent_cache_stats(sys.argv[1])['files'])\n")
    env = dict(os.environ, PYTHONPATH=src)
    runs = []
    for _ in range(2):
        out = subprocess.run([sys.executable, "-c", code, d],
                             capture_output=True, text=True, env=env,
                             timeout=300)
        assert out.returncode == 0, out.stderr
        runs.append(int(out.stdout.strip().splitlines()[-1]))
    assert runs[0] > 0                 # first run wrote executables
    assert runs[1] == runs[0]          # second run loaded, didn't re-write


def test_persistent_cache_stats_missing_dir():
    st = programs.persistent_cache_stats("/nonexistent/xla-cache")
    assert st["files"] == 0 and st["bytes"] == 0


# --------------------------------------------------- subprocess harness
@pytest.mark.skipif(_HEAVY, reason="already inside the harness")
def test_aot_service_suite_isolated():
    """Run every @heavy test in a fresh interpreter (see the _HEAVY note
    at the top of this file).  One subprocess amortises the import cost
    across all of them; -p no:cacheprovider keeps the child from
    touching the parent's .pytest_cache."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ, REPRO_PROGRAMS_HEAVY="1")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), env.get("PYTHONPATH", "")])
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__)],
        capture_output=True, text=True, env=env, cwd=root, timeout=1200)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    tail = out.stdout.strip().splitlines()[-1]
    assert " passed" in tail and "failed" not in tail, tail
