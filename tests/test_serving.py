"""Serving observability plane tests (repro.obs.serving, DESIGN.md §14).

Three contracts: (1) the labeled-family registry + Prometheus renderer +
SLO tracker produce correct, parseable exposition; (2) the /metrics
endpoint serves live state from a background thread without perturbing
the service; (3) request-scoped correlation — a ≥2-tenant replay yields
a recoverable span chain per request_id, per-tenant SLO families in the
Prometheus text, well-formed traces/event logs under the validator, and
results bitwise identical to the same replay with every serving-plane
feature switched off (telemetry neutrality extends to the new plane).
"""
import dataclasses
import json
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.core import aco, tsp
from repro.obs import serving, validate
from repro.solver import streaming
from repro.solver.service import SolverService


# ------------------------------------------------------- labeled families
def test_registry_labeled_families():
    r = obs.Registry()
    plain = r.counter("reqs")
    a = r.counter("reqs", tenant="a")
    b = r.counter("reqs", tenant="b")
    assert plain is not a and a is not b
    assert r.counter("reqs", tenant="a") is a       # same labels → same
    plain.inc()
    a.inc(2)
    b.inc(3)
    snap = r.snapshot()
    assert snap["counters"]["reqs"] == 1            # unlabeled stays bare
    assert snap["counters"]['reqs{tenant="a"}'] == 2
    assert snap["counters"]['reqs{tenant="b"}'] == 3
    # label order is canonical: kwargs order doesn't mint new children
    g1 = r.gauge("occ", dev="0", bucket="32")
    g2 = r.gauge("occ", bucket="32", dev="0")
    assert g1 is g2
    fams = list(r.families())
    assert ("reqs", {"tenant": "a"}, "counter", a) in fams
    kinds = {k for (_, _, k, _) in fams}
    assert kinds == {"counter", "gauge"}


def test_histogram_percentile_edge_contract():
    h = obs.Registry().histogram("lat", window=4)
    assert h.percentile(50) == 0.0                  # empty → 0.0
    h.observe(7.0)
    for q in (0, 50, 99, 100):                      # single sample → it
        assert h.percentile(q) == 7.0
    assert h.percentile(-5) == 7.0 and h.percentile(500) == 7.0  # clamped
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):             # overflow the window
        h.observe(v)
    assert h.count == 6 and h.total == 22.0         # exact aggregates
    assert h.max() == 7.0                           # vmax survives window
    assert h.percentile(100) == 5.0                 # window-local p100
    s = h.summary()
    assert s["count"] == 6 and s["max"] == 7.0


# ------------------------------------------------------------- slo tracker
def test_slo_tracker_attainment_and_summary():
    slo = serving.SloTracker(obs.Registry())
    slo.on_submit("a")
    slo.on_submit("a")
    slo.on_submit(None)                             # → "default"
    slo.on_reject("b")
    slo.on_admit("a", wait_s=0.1)
    slo.on_admit("a", wait_s=0.2)
    slo.on_outcome("a", "completed", latency_s=0.5, deadline=1.0)   # met
    slo.on_outcome("a", "completed", latency_s=2.0, deadline=1.0)   # late
    slo.on_outcome("b", "expired_waiting", latency_s=3.0, deadline=2.0)
    with pytest.raises(ValueError, match="outcome"):
        slo.on_outcome("a", "vanished", 0.0, None)
    assert slo.tenants == {"a", "b", "default"}
    s = slo.summary()
    assert s["a"]["submitted"] == 2 and s["a"]["admitted"] == 2
    assert s["a"]["completed"] == 2 and s["a"]["met"] == 1
    assert s["a"]["attainment"] == pytest.approx(0.5)
    assert s["b"]["rejected"] == 1 and s["b"]["expired_waiting"] == 1
    assert s["b"]["attainment"] == 0.0
    assert s["default"]["submitted"] == 1 and s["default"]["terminated"] == 0
    assert s["a"]["latency_s"]["count"] == 2
    assert json.loads(json.dumps(s)) == s


# ---------------------------------------------------- prometheus renderer
def test_render_prometheus_text():
    r = obs.Registry()
    r.counter("reqs").inc(4)
    r.counter("reqs", tenant="a").inc(2)
    r.gauge("occupancy").set(0.75)
    h = r.histogram("lat_s", window=8, tenant='we"ird\\')
    h.observe(1.0)
    h.observe(3.0)
    r.gauge("bad name!").set(float("nan"))
    text = serving.render_prometheus(r)
    lines = text.splitlines()
    assert "# TYPE repro_reqs counter" in lines
    assert lines.count("# TYPE repro_reqs counter") == 1   # one per family
    assert "repro_reqs 4" in lines
    assert 'repro_reqs{tenant="a"} 2' in lines
    assert "# TYPE repro_occupancy gauge" in lines
    assert "repro_occupancy 0.75" in lines
    # histograms expose quantiles + _sum/_count/_max; labels escaped and
    # canonically sorted (quantile < tenant)
    esc = 'tenant="we\\"ird\\\\"'
    assert f'repro_lat_s{{quantile="0.5",{esc}}} 2.0' in lines
    assert f"repro_lat_s_sum{{{esc}}} 4.0" in lines
    assert f"repro_lat_s_count{{{esc}}} 2" in lines
    assert f"repro_lat_s_max{{{esc}}} 3.0" in lines
    assert "repro_bad_name_ NaN" in lines                  # sanitized name
    assert text.endswith("\n")


# ------------------------------------------------------- metrics endpoint
def _get(url: str):
    with urllib.request.urlopen(url, timeout=5.0) as resp:
        return resp.status, resp.headers.get("Content-Type"), resp.read()


def test_metrics_server_endpoints():
    cfg = aco.ACOConfig(iterations=3)
    svc = streaming.StreamingSolverService(cfg, max_batch=2, min_bucket=16)
    server = obs.MetricsServer(
        svc.tel, health_fn=svc.health,
        snapshot_extra_fn=lambda: {"stats": svc.stats}, port=0)
    try:
        assert server.port > 0                      # ephemeral port bound
        svc.submit(tsp.random_instance(10, seed=0), tenant="acme")
        svc.run_until_drained()

        status, ctype, body = _get(server.url("/metrics"))
        text = body.decode()
        assert status == 200 and ctype.startswith("text/plain")
        assert "0.0.4" in ctype                     # exposition version
        assert 'repro_slo_completed{tenant="acme"} 1' in text
        assert 'repro_slo_attainment{tenant="acme"} 1.0' in text

        status, ctype, body = _get(server.url("/healthz"))
        health = json.loads(body)
        assert status == 200 and ctype.startswith("application/json")
        assert health["ok"] is True and health["uptime_s"] >= 0
        assert health["mode"] == "streaming"
        assert "acme" in health["tenants"]

        status, _, body = _get(server.url("/snapshot"))
        snap = json.loads(body)
        assert status == 200 and snap["schema"] == "repro.obs/v1"
        assert snap["stats"]["completed"] == 1

        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(server.url("/nope"))
        assert ei.value.code == 404
    finally:
        server.close()
        svc.tel.close()
    server.close()                                  # close is idempotent


def test_drain_service_health_and_slo():
    svc = SolverService(aco.ACOConfig(iterations=3), max_batch=2)
    for i, t in enumerate(("x", None, "x")):
        svc.submit(tsp.random_instance(10 + i, seed=i), tenant=t)
    res = svc.run()
    assert len(res) == 3
    assert {r.tenant for r in res} == {"x", None}
    assert all(r.trace_id for r in res)
    h = svc.health()
    assert h["mode"] == "drain" and h["pending"] == 0
    s = svc.slo.summary()
    assert s["x"]["completed"] == 2 and s["x"]["attainment"] == 1.0
    assert s["default"]["completed"] == 1


# --------------------------------------- request correlation, two tenants
def _replay(tenants, with_endpoint, events_path=None):
    cfg = aco.ACOConfig(iterations=6, metrics=True)
    tel = obs.Telemetry(events_path=events_path)
    svc = streaming.StreamingSolverService(
        cfg, max_batch=2, min_bucket=16, telemetry=tel,
        snapshot_every=1e-6)
    trace = streaming.make_poisson_trace(
        6, rate=1e9, min_n=10, max_n=14, seed=3,
        iterations=(4, 7), tenants=tenants)
    server = obs.MetricsServer(tel, health_fn=svc.health, port=0) \
        if with_endpoint else None
    try:
        res = streaming.replay_trace(svc, trace)
    finally:
        prom = _get(server.url("/metrics"))[2].decode() if server else None
        if server:
            server.close()
        tel.close()
    return svc, sorted(res, key=lambda r: r.request_id), prom


def test_two_tenant_replay_correlation_slo_and_parity(tmp_path):
    ref_svc, ref, _ = _replay(tenants=None, with_endpoint=False)
    svc, res, prom = _replay(tenants=("t-a", "t-b"), with_endpoint=True,
                             events_path=str(tmp_path / "events.jsonl"))

    # (a) serving plane is bitwise-neutral: labels + live endpoint change
    # nothing about the solves
    assert len(res) == len(ref) == 6
    for a, b in zip(ref, res):
        assert a.best_len == b.best_len
        np.testing.assert_array_equal(a.best_tour, b.best_tour)
    assert {r.tenant for r in res} == {"t-a", "t-b"}

    # (b) recoverable span chain per request_id: each request shows up as
    # a queued span, a residency span, and the chunk dispatches it was
    # resident for — and every span naming its trace_id agrees with it
    trace = svc.tel.tracer.to_chrome()
    for r in res:
        chain = svc.tel.tracer.request_chain(r.request_id)
        names = [ev["name"] for ev in chain]
        assert any(n.startswith("queued req") for n in names)
        assert f"req{r.request_id}" in names
        assert "chunk_dispatch" in names
        tids = {ev["args"]["trace_id"] for ev in chain
                if "trace_id" in ev["args"]}
        assert tids == {r.trace_id}
    events = list(svc.tel.events.records())
    for r in res:
        kinds = {e["kind"] for e in events
                 if e.get("request_id") == r.request_id}
        assert {"submit", "admit", "harvest"} <= kinds
        for e in events:
            if e.get("request_id") == r.request_id:
                assert e["trace_id"] == r.trace_id
                assert e["tenant"] == r.tenant

    # (c) per-tenant SLO reaches the Prometheus exposition
    assert 'repro_slo_completed{tenant="t-a"} 3' in prom
    assert 'repro_slo_completed{tenant="t-b"} 3' in prom
    assert 'repro_slo_attainment{tenant="t-a"} 1.0' in prom
    assert "repro_slo_latency_s" in prom
    st = svc.stats
    assert set(st["tenants"]) == {"t-a", "t-b"}
    assert st["uptime_s"] > 0

    # (d) everything emitted validates: chrome trace + event-log mirror
    assert validate.validate_chrome_trace(trace) == len(trace["traceEvents"])
    assert validate.validate_event_log_file(
        str(tmp_path / "events.jsonl")) > 0


def test_snapshot_fires_immediately_with_uptime():
    cfg = aco.ACOConfig(iterations=2)
    svc = streaming.StreamingSolverService(cfg, max_batch=2, min_bucket=16,
                                           snapshot_every=3600.0)
    svc.submit(tsp.random_instance(10, seed=0))
    svc.run_until_drained()
    snaps = [e for e in svc.tel.events.records()
             if e["kind"] == "stats_snapshot"]
    assert len(snaps) == 1                  # first fires immediately, the
    assert snaps[0]["uptime_s"] >= 0        # hour-long cadence never hits
    assert svc.stats["uptime_s"] >= snaps[0]["uptime_s"]


def test_expired_waiting_request_has_span_and_slo():
    cfg = aco.ACOConfig(iterations=2)
    svc = streaming.StreamingSolverService(cfg, max_batch=1, min_bucket=16)
    svc.submit(tsp.random_instance(10, seed=0), tenant="slow",
               deadline=1e-6)
    import time
    time.sleep(0.01)
    res = svc.run_until_drained()
    assert len(res) == 1 and res[0].expired and res[0].tenant == "slow"
    s = svc.slo.summary()
    assert s["slow"]["expired_waiting"] == 1
    assert s["slow"]["attainment"] == 0.0
    names = [e["name"] for e in svc.tel.tracer.to_chrome()["traceEvents"]]
    assert any(n.startswith("queued req") and n.endswith("!")
               for n in names)             # expired-in-queue span marker


# --------------------------------------------------------------- validator
def test_validator_rejects_malformed():
    with pytest.raises(validate.TraceValidationError, match="ph"):
        validate.validate_chrome_trace([{"pid": 1, "tid": 1, "name": "x"}])
    with pytest.raises(validate.TraceValidationError, match="ts"):
        validate.validate_chrome_trace(
            [{"ph": "X", "pid": 1, "tid": 1, "name": "x", "dur": 1}])
    with pytest.raises(validate.TraceValidationError, match="dur"):
        validate.validate_chrome_trace(
            [{"ph": "X", "pid": 1, "tid": 1, "name": "x", "ts": 0,
              "dur": -5}])
    ok = [{"ph": "X", "pid": 1, "tid": 1, "name": "x", "ts": 0, "dur": 2}]
    assert validate.validate_chrome_trace(ok) == 1
    assert validate.validate_chrome_trace({"traceEvents": ok}) == 1

    with pytest.raises(validate.TraceValidationError, match="kind"):
        validate.validate_event_log([{"t": 0.0}])
    with pytest.raises(validate.TraceValidationError, match="request_id"):
        validate.validate_event_log(
            [{"t": 0.0, "kind": "harvest", "trace_id": "x", "tenant": "d"}])
    assert validate.validate_event_log(
        [json.dumps({"t": 0.0, "kind": "reject"}),
         {"t": 1.0, "kind": "harvest", "request_id": 0,
          "trace_id": "ab", "tenant": "default"}]) == 2
