"""Checkpoint/restart fault-tolerance tests."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck
from repro.core import aco, tsp


def test_atomic_save_and_restore(tmp_path):
    inst = tsp.random_instance(16, seed=0)
    cfg = aco.ACOConfig(iterations=3)
    st = aco.run(inst, cfg)
    path = str(tmp_path / "c.npz")
    ck.save_pytree(path, st, step=3)
    rest = ck.load_pytree(path, st)
    np.testing.assert_array_equal(np.asarray(rest.tau), np.asarray(st.tau))
    assert int(rest.iteration) == 3


def test_restart_resumes_exactly(tmp_path):
    """Kill-and-restart must produce the same trajectory as uninterrupted."""
    inst = tsp.random_instance(20, seed=1)
    cfg = aco.ACOConfig(iterations=6, selection="gumbel")
    full = aco.run(inst, cfg)

    mgr = ck.CheckpointManager(str(tmp_path), async_write=False)
    half_cfg = aco.ACOConfig(iterations=3, selection="gumbel")
    st = aco.run(inst, half_cfg)
    mgr.save(3, st)
    # simulated crash; new process restores and continues
    restored, step = mgr.restore(st)
    assert step == 3
    resumed = aco.run(inst, cfg, state=restored)
    np.testing.assert_allclose(np.asarray(resumed.tau), np.asarray(full.tau),
                               rtol=1e-6)
    assert float(resumed.best_len) == float(full.best_len)


@pytest.mark.parametrize("tau_dtype", ["int8", "bf16"])
def test_quantised_state_roundtrip_bit_exact(tmp_path, tau_dtype):
    """QuantTau leaves (int8/bf16 payload, per-row scales, zero-width err)
    survive save/load bit-exact — bf16 rides as raw uint16 bits in the
    npz, so no value can be perturbed by a dtype bounce."""
    inst = tsp.random_instance(16, seed=4)
    cfg = aco.ACOConfig(iterations=3, tau_dtype=tau_dtype,
                        selection="gumbel")
    st = aco.run(inst, cfg)
    path = str(tmp_path / "q.npz")
    ck.save_pytree(path, st, step=3)
    rest = ck.load_pytree(path, st)
    assert rest.tau.q.dtype == st.tau.q.dtype
    q0, q1 = np.asarray(st.tau.q), np.asarray(rest.tau.q)
    if tau_dtype == "bf16":
        q0, q1 = q0.view(np.uint16), q1.view(np.uint16)
    np.testing.assert_array_equal(q0, q1)
    np.testing.assert_array_equal(np.asarray(st.tau.scale),
                                  np.asarray(rest.tau.scale))
    assert rest.tau.err.shape == st.tau.err.shape     # zero-width survives
    assert float(rest.best_len) == float(st.best_len)


def test_quantised_restart_resumes_bitwise(tmp_path):
    """Kill-and-restart over a quantised store reproduces the
    uninterrupted trajectory bitwise: the PRNG trajectory (including the
    quantise-on-store split) lives in the state, and the resident payload
    is restored bit-for-bit, so requantisation cannot drift."""
    inst = tsp.random_instance(20, seed=1)
    cfg = aco.ACOConfig(iterations=6, selection="gumbel", tau_dtype="int8",
                        variant="mmas")
    full = aco.run(inst, cfg)
    mgr = ck.CheckpointManager(str(tmp_path), async_write=False)
    st = aco.run(inst, aco.ACOConfig(iterations=3, selection="gumbel",
                                     tau_dtype="int8", variant="mmas"))
    mgr.save(3, st)
    restored, step = mgr.restore(st)
    assert step == 3
    resumed = aco.run(inst, cfg, state=restored)
    np.testing.assert_array_equal(np.asarray(resumed.tau.q),
                                  np.asarray(full.tau.q))
    np.testing.assert_array_equal(np.asarray(resumed.tau.scale),
                                  np.asarray(full.tau.scale))
    assert float(resumed.best_len) == float(full.best_len)
    np.testing.assert_array_equal(np.asarray(resumed.key),
                                  np.asarray(full.key))


def test_manager_retention_and_latest(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(4), "b": jnp.ones((2, 2))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_writer(tmp_path):
    mgr = ck.CheckpointManager(str(tmp_path), keep=5, async_write=True)
    tree = {"x": jnp.full((32, 32), 7.0)}
    for s in range(3):
        mgr.save(s, tree)
    mgr.wait()
    assert mgr.all_steps() == [0, 1, 2]
    rest, step = mgr.restore(tree)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(rest["x"]), 7.0)


def test_no_partial_checkpoint_on_disk(tmp_path):
    """Interrupted writes leave only .tmp files, never a truncated ckpt."""
    mgr = ck.CheckpointManager(str(tmp_path), async_write=False)
    tree = {"x": jnp.zeros(8)}
    mgr.save(0, tree)
    files = os.listdir(tmp_path)
    assert files == ["ckpt_000000000.npz"]
    # a stale tmp file must not break restore
    open(tmp_path / "ckpt_000000001.npz.tmp", "w").close()
    rest, step = mgr.restore(tree)
    assert step == 0
