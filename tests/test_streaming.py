"""Continuous-batching streaming solver tests (DESIGN.md §9).

The load-bearing property is the exactness contract: any request solved
through the streaming pool — admitted mid-run into a slot freed by a
harvested sibling — yields bitwise the same best tour as a solo
engine.run_batch call with the same seed.  Refill surgery must never
perturb resident siblings, and chunked stepping must compose exactly.
"""
import numpy as np
import pytest

from repro.core import aco, tsp
from repro.solver import engine, streaming

INSTS = (tsp.random_instance(10, seed=1), tsp.circle_instance(12, seed=2),
         tsp.random_instance(13, seed=3), tsp.circle_instance(16, seed=4),
         tsp.random_instance(14, seed=5))
BUDGETS = (6, 3, 7, 4, 5)
SEEDS = (20, 21, 22, 23, 24)


def _solo(inst, cfg, iterations, seed, n_pad=16, hypers=None):
    st, _ = engine.solve_instances([inst], cfg, iterations=[iterations],
                                   seeds=[seed], n_pad=n_pad, hypers=hypers)
    return (float(np.asarray(st.best_len)[0]),
            np.asarray(st.best_tour)[0][:inst.n])


# ---------------------------------------------------------------- exactness
@pytest.mark.parametrize("variant,ls", [
    ("as", "none"), ("mmas", "none"), ("acs", "none"), ("as", "2opt"),
])
def test_streaming_exactness_with_midrun_admission(variant, ls):
    """5 requests through 2 slots with chunk=2: every slot is refilled at
    least once mid-run, and two requests arrive while the pool is already
    stepping.  Every result must be bitwise the solo result."""
    cfg = aco.ACOConfig(iterations=max(BUDGETS), variant=variant,
                        selection="gumbel", local_search=ls, ls_rounds=4)
    svc = streaming.StreamingSolverService(cfg, max_batch=2, min_bucket=16,
                                           chunk=2)
    for k in range(3):
        svc.submit(INSTS[k], iterations=BUDGETS[k], seed=SEEDS[k])
    results = list(svc.step()) + list(svc.step())
    for k in range(3, 5):      # arrive mid-run, join a partially done pool
        svc.submit(INSTS[k], iterations=BUDGETS[k], seed=SEEDS[k])
    results.extend(svc.run_until_drained())

    assert len(results) == len(INSTS)
    assert svc.stats["fills"] == len(INSTS)    # refills actually happened
    by_id = {r.request_id: r for r in results}
    for k, inst in enumerate(INSTS):
        best_len, best_tour = _solo(inst, cfg, BUDGETS[k], SEEDS[k])
        r = by_id[k]
        assert r.best_len == best_len, (variant, ls, k)
        np.testing.assert_array_equal(r.best_tour, best_tour)
        assert r.iterations == BUDGETS[k]
        assert tsp.is_valid_tour(r.best_tour)


def test_streaming_chunk_size_is_unobservable():
    """The harvested result must not depend on the chunk granularity."""
    cfg = aco.ACOConfig(iterations=max(BUDGETS), selection="gumbel")
    outs = []
    for chunk in (1, 3):
        svc = streaming.StreamingSolverService(cfg, max_batch=2,
                                               min_bucket=16, chunk=chunk)
        for k, inst in enumerate(INSTS):
            svc.submit(inst, iterations=BUDGETS[k], seed=SEEDS[k])
        outs.append({r.request_id: r for r in svc.run_until_drained()})
    for k in range(len(INSTS)):
        assert outs[0][k].best_len == outs[1][k].best_len
        np.testing.assert_array_equal(outs[0][k].best_tour,
                                      outs[1][k].best_tour)


def test_streaming_multi_bucket_pools():
    """Requests landing in different buckets run in independent pools."""
    cfg = aco.ACOConfig(iterations=4, selection="gumbel")
    svc = streaming.StreamingSolverService(cfg, max_batch=2, min_bucket=16,
                                           chunk=2)
    sizes = (10, 20, 14, 28)
    for i, n in enumerate(sizes):
        svc.submit(tsp.circle_instance(n, seed=n), iterations=4, seed=i)
    results = svc.run_until_drained()
    assert {r.bucket for r in results} == {16, 32}
    for r, n in zip(sorted(results, key=lambda r: r.request_id), sizes):
        assert r.n == n and len(r.best_tour) == n
        assert tsp.is_valid_tour(r.best_tour)
        best_len, best_tour = _solo(
            tsp.circle_instance(n, seed=n), cfg, 4,
            list(sizes).index(n), n_pad=r.bucket)
        assert r.best_len == best_len


# ---------------------------------------------------------------- admission
def test_admission_priority_and_deadline_order():
    """With one slot, completion order is admission order: higher priority
    first, then earlier deadline, then arrival."""
    cfg = aco.ACOConfig(iterations=2, selection="gumbel")
    svc = streaming.StreamingSolverService(cfg, max_batch=1, min_bucket=16,
                                           chunk=2)
    a = svc.submit(INSTS[0], priority=0, seed=1)
    b = svc.submit(INSTS[1], priority=5, deadline=100.0, seed=2)
    c = svc.submit(INSTS[2], priority=5, deadline=50.0, seed=3)
    d = svc.submit(INSTS[3], priority=5, seed=4)   # no deadline: after b/c
    done = [r.request_id for r in svc.run_until_drained()]
    assert done == [c, b, d, a]


def test_admission_backpressure_max_waiting():
    cfg = aco.ACOConfig(iterations=2, selection="gumbel")
    svc = streaming.StreamingSolverService(cfg, max_batch=1, min_bucket=16,
                                           chunk=2, max_waiting=2)
    svc.submit(INSTS[0], seed=1)
    svc.submit(INSTS[1], seed=2)
    with pytest.raises(streaming.AdmissionError):
        svc.submit(INSTS[2], seed=3)
    assert svc.stats["rejected"] == 1
    # draining the queue frees admission capacity again
    svc.run_until_drained()
    svc.submit(INSTS[2], seed=3)
    assert svc.waiting == 1


def test_streaming_rejects_pallas_hyper_and_unknown_deposit():
    from repro.kernels import ops as kops
    # mask-aware kernel routes: plain use_pallas streaming is supported now;
    # only per-instance Hyper operands remain kernel-incompatible (static
    # kernel exponents) and fail eagerly with the kernels' typed error.
    streaming.StreamingSolverService(aco.ACOConfig(use_pallas=True))
    with pytest.raises(kops.UnsupportedKernelRoute, match="Hyper"):
        streaming.StreamingSolverService(aco.ACOConfig(use_pallas=True),
                                         per_instance_hyper=True)
    with pytest.raises(ValueError, match="deposit"):
        streaming.StreamingSolverService(aco.ACOConfig(deposit="nope"))


# ------------------------------------------- deadline eviction (hardening)
def test_evict_expired_from_waiting_queue():
    """A waiting request whose latency budget lapses before admission is
    evicted (never runs): expired result with empty tour, counted in
    stats, and it does not block the drain loop."""
    import time
    cfg = aco.ACOConfig(iterations=2, selection="gumbel")
    svc = streaming.StreamingSolverService(cfg, max_batch=1, min_bucket=16,
                                           chunk=2)
    live = svc.submit(INSTS[0], iterations=2, seed=1)
    doomed = svc.submit(INSTS[1], iterations=2, seed=2, deadline=1e-9)
    time.sleep(0.01)           # the budget has certainly lapsed
    results = {r.request_id: r for r in svc.run_until_drained()}
    assert results[doomed].expired
    assert results[doomed].iterations == 0
    assert results[doomed].best_len == float("inf")
    assert results[doomed].best_tour.size == 0
    assert not results[live].expired
    s = svc.stats
    assert s["expired"] == 1 and s["expired_waiting"] == 1
    assert s["completed"] == 1          # expired results don't count


def test_evict_expired_running_slot_returns_partial_best():
    """Pool-level determinism: an occupied slot whose request expired is
    freed with the best tour found so far, siblings untouched bitwise."""
    import time
    cfg = aco.ACOConfig(iterations=10, selection="gumbel")
    pool = streaming.StreamingPool(16, 2, cfg)
    now = time.perf_counter()
    doomed = streaming.StreamRequest(
        request_id=0, instance=INSTS[0], iterations=10, seed=7,
        submitted_at=now, deadline=0.001, expires_at=now + 0.001)
    sibling = streaming.StreamRequest(
        request_id=1, instance=INSTS[1], iterations=4, seed=8,
        submitted_at=now)
    pool.fill_slots([(0, doomed), (1, sibling)])
    pool.step_chunk(2)                      # both make progress
    got = pool.evict_expired(now + 10.0)    # doomed is past its expiry
    assert [r.request_id for r in got] == [0]
    assert got[0].expired and got[0].iterations == 2
    assert np.isfinite(got[0].best_len)     # partial best, not inf
    assert tsp.is_valid_tour(got[0].best_tour)
    assert pool.free_slots() == [0]
    # the sibling keeps running to completion, bitwise its solo result
    pool.step_chunk(2)
    done = pool.harvest()
    assert [r.request_id for r in done] == [1]
    best_len, best_tour = _solo(INSTS[1], cfg, 4, 8)
    assert done[0].best_len == best_len
    np.testing.assert_array_equal(done[0].best_tour, best_tour)


def test_evicted_slot_is_refilled_exactly():
    """A running slot evicted mid-run frees through the same budget-0 path
    as harvest, so the ordinary refill surgery reuses it and the newcomer
    still reproduces its solo run bitwise."""
    import time
    cfg = aco.ACOConfig(iterations=30, selection="gumbel")
    svc = streaming.StreamingSolverService(cfg, max_batch=1, min_bucket=16,
                                           chunk=1)
    hog = svc.submit(INSTS[0], iterations=30, seed=1)   # hogs the one slot
    succ = svc.submit(INSTS[1], iterations=3, seed=2)
    assert svc.step() == []                 # hog admitted and stepping
    pool = svc._pools[16][0]
    assert pool.requests[0].request_id == hog
    # force the hog's latency budget to lapse mid-run (deterministic —
    # no wall-clock race) and let the scheduler evict + refill
    pool.requests[0].expires_at = time.perf_counter() - 1.0
    results = {r.request_id: r for r in svc.run_until_drained()}
    assert results[hog].expired
    assert results[hog].iterations >= 1     # it really ran before eviction
    assert not results[succ].expired
    best_len, best_tour = _solo(INSTS[1], cfg, 3, 2)
    assert results[succ].best_len == best_len
    np.testing.assert_array_equal(results[succ].best_tour, best_tour)
    s = svc.stats
    assert s["expired"] == 1 and s["expired_running"] == 1
    assert s["fills"] == 2                  # the freed slot was refilled


def test_streaming_stats_shape():
    cfg = aco.ACOConfig(iterations=3, selection="gumbel")
    svc = streaming.StreamingSolverService(cfg, max_batch=2, min_bucket=16,
                                           chunk=1)
    for k, inst in enumerate(INSTS[:3]):
        svc.submit(inst, iterations=3, seed=k)
    svc.run_until_drained()
    s = svc.stats
    assert s["submitted"] == 3 and s["completed"] == 3
    assert s["waiting"] == 0 and s["resident"] == 0
    assert s["fills"] == 3 and s["chunks"] >= 3
    assert 0.0 < s["occupancy_mean"] <= 1.0
    assert s["instances_per_s"] > 0
    assert s["latency_p50_s"] <= s["latency_p95_s"] <= s["latency_max_s"]


# ------------------------------------------------- per-instance hyper (§9)
def test_streaming_mixed_hyper_profiles_exact():
    """One pool mixes tuning profiles; each request still reproduces its
    solo run (same profile, same seed) bitwise."""
    cfg = aco.ACOConfig(iterations=5, variant="mmas", selection="gumbel")
    profiles = [None, {"alpha": 2.0, "rho": 0.3}, {"beta": 3.0, "q": 2.0},
                {"rho": 0.8}, {"alpha": 1.5, "beta": 1.0}]
    svc = streaming.StreamingSolverService(cfg, max_batch=2, min_bucket=16,
                                           chunk=2, per_instance_hyper=True)
    for k, inst in enumerate(INSTS):
        svc.submit(inst, iterations=BUDGETS[k], seed=SEEDS[k],
                   hyper=profiles[k])
    results = {r.request_id: r for r in svc.run_until_drained()}
    for k, inst in enumerate(INSTS):
        h = aco.Hyper.make(cfg, **(profiles[k] or {}))
        best_len, best_tour = _solo(inst, cfg, BUDGETS[k], SEEDS[k],
                                    hypers=[h])
        assert results[k].best_len == best_len, k
        np.testing.assert_array_equal(results[k].best_tour, best_tour)


def test_streaming_hyper_requires_flag():
    svc = streaming.StreamingSolverService(aco.ACOConfig(iterations=2))
    with pytest.raises(ValueError, match="per_instance_hyper"):
        svc.submit(INSTS[0], hyper={"alpha": 2.0})


# ------------------------------------------- quantised resident tau (§15)
@pytest.mark.parametrize("tau_dtype", ["int8", "bf16"])
def test_streaming_quantised_exactness_with_refill(tau_dtype):
    """Quantised ColonyState leaves (int8/bf16 payload + per-row scales)
    ride the same slot-surgery paths: 5 requests through 2 slots with
    mid-run admission still reproduce their solo runs bitwise."""
    cfg = aco.ACOConfig(iterations=max(BUDGETS), variant="mmas",
                        selection="gumbel", tau_dtype=tau_dtype)
    svc = streaming.StreamingSolverService(cfg, max_batch=2, min_bucket=16,
                                           chunk=2)
    for k in range(3):
        svc.submit(INSTS[k], iterations=BUDGETS[k], seed=SEEDS[k])
    results = list(svc.step()) + list(svc.step())
    for k in range(3, 5):
        svc.submit(INSTS[k], iterations=BUDGETS[k], seed=SEEDS[k])
    results.extend(svc.run_until_drained())
    assert len(results) == len(INSTS)
    assert svc.stats["fills"] == len(INSTS)
    by_id = {r.request_id: r for r in results}
    for k, inst in enumerate(INSTS):
        best_len, best_tour = _solo(inst, cfg, BUDGETS[k], SEEDS[k])
        assert by_id[k].best_len == best_len, (tau_dtype, k)
        np.testing.assert_array_equal(by_id[k].best_tour, best_tour)
        assert tsp.is_valid_tour(by_id[k].best_tour)


# ------------------------------------------------------------ trace replay
def test_replay_retries_on_backpressure():
    """A bounded-queue service pushes back mid-replay; replay_trace must
    hold items at the full-queue boundary and retry after draining instead
    of crashing, still completing every request exactly."""
    trace = streaming.make_poisson_trace(6, rate=1e6, min_n=10, max_n=16,
                                         seed=4, iterations=3)
    cfg = aco.ACOConfig(iterations=3, selection="gumbel")
    svc = streaming.StreamingSolverService(cfg, max_batch=1, min_bucket=16,
                                           chunk=3, max_waiting=1)
    results = streaming.replay_trace(svc, trace)
    assert len(results) == 6
    assert svc.stats["rejected"] == 0   # client-side hold, no retry spam
    for t, r in zip(trace, sorted(results, key=lambda r: r.request_id)):
        best_len, _ = _solo(t.instance, cfg, t.iterations, t.seed)
        assert r.best_len == best_len
    with pytest.raises(ValueError, match="max_waiting"):
        streaming.StreamingSolverService(cfg, max_waiting=0)


def test_poisson_trace_and_replay():
    trace = streaming.make_poisson_trace(6, rate=200.0, min_n=10, max_n=16,
                                         seed=3, iterations=(2, 5))
    assert len(trace) == 6
    assert all(trace[i].at <= trace[i + 1].at for i in range(5))
    assert {t.iterations for t in trace} <= {2, 5}
    cfg = aco.ACOConfig(iterations=5, selection="gumbel")
    svc = streaming.StreamingSolverService(cfg, max_batch=2, min_bucket=16,
                                           chunk=2)
    results = streaming.replay_trace(svc, trace)
    assert len(results) == 6
    for t, r in zip(trace, sorted(results, key=lambda r: r.request_id)):
        best_len, best_tour = _solo(t.instance, cfg, t.iterations, t.seed)
        assert r.best_len == best_len
        np.testing.assert_array_equal(r.best_tour, best_tour)
