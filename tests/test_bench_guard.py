"""Bench manifest + regression guard tests (benchmarks/{manifest,regress}).

The guard must be trustworthy in both directions: committed-vs-committed
always passes (the --dry CI lane), and a tampered fresh value outside its
tolerance band is flagged.  These tests run against a synthetic bench
root so they are immune to the real BENCH files drifting.
"""
import json
import os

import pytest

from benchmarks import manifest, regress

STREAMING_PAYLOAD = {
    "benchmark": "streaming_throughput", "unix_time": 1,
    "rows": [
        {"mode": "drain", "ips": 20.0, "lat_mean_s": 0.5},
        {"mode": "streaming", "ips": 30.0, "lat_mean_s": 0.2},
    ],
    "summary": {"ips_ratio": 1.5, "lat_mean_ratio": 0.4,
                "tau_ratio_bf16": 2.0, "tau_ratio_int8": 3.5},
    "residency": [
        {"tau_dtype": "fp32", "state_bytes_per_slot": 4240,
         "slots_per_gb": 235849},
        {"tau_dtype": "bf16", "state_bytes_per_slot": 2192,
         "slots_per_gb": 456204},
        {"tau_dtype": "int8", "state_bytes_per_slot": 1296,
         "slots_per_gb": 771604},
    ],
}

OBS_PAYLOAD = {
    "benchmark": "obs_overhead", "unix_time": 2,
    "rows": [
        {"level": "off", "ips": 10.0, "lat_mean_s": 0.1,
         "occupancy_mean": 0.5},
        {"level": "events", "ips": 9.9, "lat_mean_s": 0.1},
        {"level": "full", "ips": 9.8, "lat_mean_s": 0.11},
        {"level": "serving", "ips": 9.7, "lat_mean_s": 0.12},
    ],
    "summary": {"full_vs_off_ips": 0.98, "overhead_pct": 2.0,
                "within_5pct": True, "serving_vs_off_ips": 0.97,
                "serving_overhead_pct": 3.0, "within_5pct_serving": True},
}


def _bench_root(tmp_path):
    root = str(tmp_path)
    with open(os.path.join(root, "BENCH_streaming.json"), "w") as f:
        json.dump(STREAMING_PAYLOAD, f)
    with open(os.path.join(root, "BENCH_obs.json"), "w") as f:
        json.dump(OBS_PAYLOAD, f)
    return root


def test_manifest_build_and_headlines(tmp_path):
    root = _bench_root(tmp_path)
    path = manifest.write_manifest(root=root)
    man = manifest.load_manifest(root=root)
    assert os.path.basename(path) == manifest.MANIFEST_NAME
    assert man["schema"] == manifest.SCHEMA
    st = man["benches"]["streaming"]
    assert st["present"] and st["unix_time"] == 1
    assert st["headline"]["ips_ratio"] == 1.5
    assert st["headline"]["streaming_ips"] == 30.0
    assert st["headline"]["drain_ips"] == 20.0
    ob = man["benches"]["obs"]["headline"]
    assert ob["serving_overhead_pct"] == 3.0
    assert ob["serving_ips"] == 9.7 and ob["off_occupancy_mean"] == 0.5
    # benches without files are listed as absent, not errors
    assert man["benches"]["solver"] == {"file": "BENCH_solver.json",
                                        "present": False}
    # corrupt payloads degrade to an extraction error, not a crash
    assert "_extract_error" in manifest.headline("streaming", {"rows": 7})
    assert manifest.headline("unknown-bench", {}) == {}


def test_regress_dry_passes_and_detects_drift(tmp_path, capsys):
    root = _bench_root(tmp_path)
    manifest.write_manifest(root=root)
    assert regress.run_checks(["streaming", "obs"], dry=True,
                              tol_scale=1.0, root=root) == 0
    assert regress.run_checks(["solver"], dry=True,
                              tol_scale=1.0, root=root) == 0  # absent→skip
    # a manifest whose stored headline disagrees with the committed file
    # is a plumbing error (stale index), not a silent pass
    man = manifest.load_manifest(root=root)
    man["benches"]["streaming"]["headline"]["ips_ratio"] = 9.9
    with open(os.path.join(root, manifest.MANIFEST_NAME), "w") as f:
        json.dump(man, f)
    assert regress.run_checks(["streaming"], dry=True,
                              tol_scale=1.0, root=root) == 3
    capsys.readouterr()


def test_regress_missing_manifest_is_plumbing_error(tmp_path):
    assert regress.run_checks(["streaming"], dry=True, tol_scale=1.0,
                              root=str(tmp_path)) == 3


@pytest.mark.parametrize("direction,committed,fresh,ok", [
    ("higher", 10.0, 7.0, True),     # within 35% band
    ("higher", 10.0, 6.0, False),    # below the floor
    ("lower", 1.0, 1.3, True),
    ("lower", 1.0, 1.5, False),
    ("match", 100.0, 101.0, True),
    ("match", 100.0, 140.0, False),
    ("match", 100.0, 60.0, False),   # match flags improvements too
])
def test_evaluate_tolerance_bands(direction, committed, fresh, ok):
    chk = regress.Check("x", "m", direction, rel=0.35, abs_slack=0.0)
    got, _ = regress.evaluate(chk, committed, fresh)
    assert got is ok


def test_evaluate_tol_scale_widens_band():
    chk = regress.Check("x", "m", "higher", rel=0.2)
    assert not regress.evaluate(chk, 10.0, 7.0)[0]
    assert regress.evaluate(chk, 10.0, 7.0, tol_scale=2.0)[0]


def test_regress_flags_regression_in_fresh_payload(tmp_path, monkeypatch):
    """End to end: a fresh run whose ips_ratio collapsed must exit 1."""
    root = _bench_root(tmp_path)
    manifest.write_manifest(root=root)
    bad = json.loads(json.dumps(STREAMING_PAYLOAD))
    bad["summary"]["ips_ratio"] = 0.5          # streaming now LOSES

    def fake_runner(out):
        with open(out, "w") as f:
            json.dump(bad, f)

    monkeypatch.setitem(regress.RUNNERS, "streaming", fake_runner)
    assert regress.run_checks(["streaming"], dry=False,
                              tol_scale=1.0, root=root) == 1
