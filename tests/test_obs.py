"""Telemetry fabric tests (repro.obs, DESIGN.md §13).

The load-bearing property is **bitwise neutrality**: turning
``ACOConfig.metrics`` on must not change a single bit of any solve —
tours, lengths, tau, PRNG keys — on any route (solo scan, batched engine,
streaming pool, sharded mesh, sparse representation).  Metrics are
read-only reductions over intermediates the step already computes; these
tests pin that contract.

Host-side surfaces (registry / tracer / event log) are tested for their
bounded-memory guarantees: exact counts and means survive window
eviction, dropped records are counted, and the Chrome-trace export is
well-formed (Perfetto-loadable) JSON.
"""
import json
import os
import subprocess
import sys
import textwrap

import dataclasses

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import aco, tsp
from repro.obs import metrics as obs_metrics
from repro.obs.registry import Histogram
from repro.solver import engine, streaming
from repro.solver.service import SolverService

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _leaves_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------- registry
def test_registry_instruments_and_snapshot():
    r = obs.Registry()
    c = r.counter("fills")
    c.inc()
    c.inc(3)
    assert r.counter("fills") is c and c.value == 4
    g = r.gauge("occ")
    g.set(0.5)
    h = r.histogram("lat", window=4)
    for v in range(1, 11):                       # window keeps only 7..10
        h.observe(float(v))
    # exact aggregates survive window eviction...
    assert h.count == 10 and h.total == 55.0
    assert h.mean() == 5.5 and h.max() == 10.0
    # ...while percentiles cover the recent window only
    assert h.percentile(0) == 7.0 and h.percentile(100) == 10.0
    snap = r.snapshot()
    assert snap["counters"] == {"fills": 4}
    assert snap["gauges"] == {"occ": 0.5}
    s = snap["histograms"]["lat"]
    assert s["count"] == 10 and s["mean"] == 5.5 and s["max"] == 10.0
    assert json.loads(json.dumps(snap)) == snap  # JSON-ready


def test_histogram_empty_and_bad_window():
    h = Histogram(window=2)
    assert h.mean() == 0.0 and h.max() == 0.0 and h.percentile(50) == 0.0
    with pytest.raises(ValueError, match="window"):
        Histogram(window=0)


# ------------------------------------------------------------------ tracer
def test_tracer_chrome_trace_format():
    t = obs.Tracer()
    with t.span("phase", process="dev0", thread="b16", k=1):
        pass
    t.complete("req0", 10.0, 25.0, process="dev0", thread="b16/s0")
    t.instant("admit", process="dev0")
    t.counter("occ", process="dev0", occupied=3)
    ch = t.to_chrome()
    evs = ch["traceEvents"]
    assert json.loads(json.dumps(ch))            # serializable
    # metadata names every (process, thread) track exactly once
    meta = [e for e in evs if e["ph"] == "M"]
    assert {(m["name"], m["args"]["name"]) for m in meta} >= {
        ("process_name", "dev0"), ("thread_name", "b16")}
    spans = [e for e in evs if e["ph"] == "X"]
    assert {s["name"] for s in spans} == {"phase", "req0"}
    for s in spans:
        assert s["dur"] >= 0 and "pid" in s and "tid" in s
    # interning is stable: same (process, thread) -> same ids
    assert t.track("dev0", "b16") == t.track("dev0", "b16")
    assert {e["ph"] for e in evs} == {"M", "X", "i", "C"}


def test_tracer_bounded():
    t = obs.Tracer(max_events=3)
    for i in range(5):
        t.instant(f"e{i}")
    assert t.dropped == 2
    assert len(t.to_chrome()["traceEvents"]) == 3 + 2   # 3 kept + 2 meta


def test_eventlog_bounded_and_file_mirror(tmp_path):
    path = str(tmp_path / "events.jsonl")
    log = obs.EventLog(path, max_records=3)
    for i in range(5):
        log.emit("tick", i=i)
    log.close()
    assert log.dropped == 2
    assert [r["i"] for r in log.records()] == [2, 3, 4]  # most recent kept
    lines = [json.loads(l) for l in open(path)]          # mirror keeps all
    assert [r["i"] for r in lines] == list(range(5))
    assert all(r["kind"] == "tick" and "t" in r for r in lines)


# ----------------------------------------------------- in-jit neutrality
@pytest.mark.parametrize("variant", ["as", "mmas", "acs"])
def test_metrics_neutral_solo_scan(variant):
    """run_scan with metrics on: identical final state bitwise, plus a
    stacked convergence curve with coherent fields."""
    inst = tsp.random_instance(14, seed=3)
    cfg = aco.ACOConfig(iterations=6, variant=variant, selection="gumbel")
    prob = aco.make_problem(inst, cfg.nn_k)
    st0 = aco.init_colony(inst, cfg)

    ref, it_best = aco.run_scan(prob, st0, cfg, 6)
    got, (it_best_m, m) = aco.run_scan(
        prob, st0, dataclasses.replace(cfg, metrics=True), 6)
    _leaves_equal(ref, got)
    np.testing.assert_array_equal(np.asarray(it_best),
                                  np.asarray(it_best_m))
    curve = {f: np.asarray(v) for f, v in zip(m._fields, m)}
    assert curve["it_best_len"].shape == (6,)
    assert np.all(curve["mean_len"] >= curve["it_best_len"] - 1e-3)
    assert np.all(curve["best_len"] <= curve["it_best_len"] + 1e-3)
    # the scan carry stamps stagnation: 0 on improving iterations
    assert np.all(curve["stagnation"][curve["improved"] == 1] == 0)
    assert np.all((curve["clamp_lo"] >= 0) & (curve["clamp_lo"] <= 1))
    if variant == "mmas":
        assert np.any(curve["clamp_lo"] > 0)     # MMAS floors fresh tau
    else:
        assert np.all(curve["clamp_lo"] == 0)    # no clamp outside MMAS


def test_metrics_neutral_batched_mixed_budgets():
    """Batched engine with heterogeneous budgets: bitwise-identical stacked
    states, and each metrics row frozen at its instance's last iteration
    (best_len row == state best_len)."""
    insts = [tsp.random_instance(n, seed=n) for n in (10, 13, 16)]
    cfg = aco.ACOConfig(iterations=7, variant="mmas")
    cfg_m = dataclasses.replace(cfg, metrics=True)
    its, seeds = [5, 7, 3], [1, 2, 3]

    ref, _ = engine.solve_instances(insts, cfg, iterations=its, seeds=seeds)
    got, b = engine.solve_instances(insts, cfg_m, iterations=its,
                                    seeds=seeds)
    _leaves_equal(ref, got)

    states = engine.init_states(insts, cfg_m, seeds, b.n_pad)
    budgets = np.asarray(its, np.int32)
    out = engine.run_batch(b.problem, states, jax.numpy.asarray(budgets),
                           cfg_m, 7)
    assert len(out) == 3
    st, since, mets = out
    for i in range(3):
        row = obs_metrics.to_host(mets, i)
        assert row["best_len"] == pytest.approx(
            float(np.asarray(st.best_len)[i]), rel=1e-6)
        assert set(row) == set(obs_metrics.FIELDS)


def test_metrics_neutral_sparse():
    """Sparse route: paged tau / overflow store bitwise identical, and the
    overflow churn counters are populated (dense rows report 0)."""
    from repro.sparse import run_sparse
    inst = tsp.random_instance(24, seed=7)
    cfg = aco.ACOConfig(iterations=5, variant="mmas", selection="gumbel",
                        sparse=True, sparse_k=8, sparse_overflow=2)
    ref = run_sparse(inst, cfg)
    got = run_sparse(inst, dataclasses.replace(cfg, metrics=True))
    _leaves_equal(ref, got)


def test_metrics_ls_accept_bounded():
    inst = tsp.random_instance(16, seed=9)
    cfg = aco.ACOConfig(iterations=4, local_search="2opt", ls_rounds=4,
                        metrics=True)
    prob = aco.make_problem(inst, cfg.nn_k)
    _, (_, m) = aco.run_scan(prob, aco.init_colony(inst, cfg), cfg, 4)
    acc = np.asarray(m.ls_accept)
    assert np.all((acc >= 0) & (acc <= 1))
    assert np.any(acc > 0)          # 2-opt improves something on random16


# ------------------------------------------------------ service routes
def _stream_solve(cfg, insts, tel=None, **kw):
    svc = streaming.StreamingSolverService(cfg, max_batch=2, min_bucket=16,
                                           chunk=2, telemetry=tel, **kw)
    for i, inst in enumerate(insts):
        svc.submit(inst, iterations=4 + i, seed=50 + i)
    res = sorted(svc.run_until_drained(),
                 key=lambda r: r.request_id)
    return svc, res


def test_metrics_neutral_streaming_with_rows():
    insts = [tsp.random_instance(n, seed=n) for n in (10, 12, 14)]
    cfg = aco.ACOConfig(iterations=8, variant="mmas")
    _, ref = _stream_solve(cfg, insts)
    _, got = _stream_solve(dataclasses.replace(cfg, metrics=True),
                           insts)
    for a, b in zip(ref, got):
        assert a.best_len == b.best_len
        np.testing.assert_array_equal(a.best_tour, b.best_tour)
        assert a.metrics is None
        assert set(b.metrics) == set(obs_metrics.FIELDS)
        assert b.metrics["best_len"] == pytest.approx(b.best_len, rel=1e-6)


def test_streaming_lifecycle_events_spans_stats(tmp_path):
    """One shared Telemetry records the full slot lifecycle as events,
    chunk dispatches + per-request residency spans on device/bucket
    tracks, and registry-backed stats with exact counts."""
    insts = [tsp.random_instance(n, seed=n) for n in (10, 12, 14)]
    cfg = aco.ACOConfig(iterations=8, metrics=True)
    tel = obs.Telemetry(events_path=str(tmp_path / "e.jsonl"))
    svc, res = _stream_solve(cfg, insts, tel=tel, snapshot_every=1e-6)
    tel.close()

    by_kind = {}
    for e in tel.events.records():
        by_kind.setdefault(e["kind"], []).append(e)
    ids = {r.request_id for r in res}
    assert {e["request_id"] for e in by_kind["submit"]} == ids
    assert {e["request_id"] for e in by_kind["admit"]} == ids
    assert {e["request_id"] for e in by_kind["harvest"]} == ids
    for e in by_kind["harvest"]:                 # metrics ride the events
        assert set(e["metrics"]) == set(obs_metrics.FIELDS)
    snaps = by_kind["stats_snapshot"]
    assert snaps and all("stats" in e and "resident_metrics" in e
                         for e in snaps)
    # the file mirror replays the same records
    mirror = [json.loads(l) for l in open(tmp_path / "e.jsonl")]
    assert len(mirror) == len(tel.events.records())

    st = svc.stats
    assert st["submitted"] == st["completed"] == len(insts)
    assert svc._h_latency.count == len(insts)
    assert 0 < st["occupancy_mean"] <= 1
    assert st["latency_max_s"] >= st["latency_p50_s"] > 0

    names = [e.get("name") for e in tel.tracer.to_chrome()["traceEvents"]]
    assert "chunk_dispatch" in names
    for rid in ids:
        assert f"req{rid}" in names              # residency span per request


def test_streaming_reject_counted():
    cfg = aco.ACOConfig(iterations=2)
    svc = streaming.StreamingSolverService(cfg, max_batch=2, max_waiting=1)
    svc.submit(tsp.random_instance(8, seed=0))
    with pytest.raises(streaming.AdmissionError):
        svc.submit(tsp.random_instance(8, seed=1))
    assert svc.stats["rejected"] == 1
    assert any(e["kind"] == "reject" for e in svc.tel.events.records())


def test_metrics_neutral_drain_service_with_checkpoint(tmp_path):
    """Drain scheduler with the Supervisor-checkpointed path: the
    checkpointed carry gains a metrics element, and results stay bitwise
    the plain metrics-off run."""
    insts = [tsp.random_instance(n, seed=n) for n in (10, 12, 14)]

    def drain(cfg, **kw):
        svc = SolverService(cfg, max_batch=2, **kw)
        for i, inst in enumerate(insts):
            svc.submit(inst, iterations=4 + i, seed=50 + i)
        return svc.run()

    ref = drain(aco.ACOConfig(iterations=8))
    got = drain(aco.ACOConfig(iterations=8, metrics=True),
                checkpoint_dir=str(tmp_path), ckpt_chunk=3)
    for a, b in zip(ref, got):
        assert a.best_len == b.best_len
        np.testing.assert_array_equal(a.best_tour, b.best_tour)
        assert a.metrics is None
        assert set(b.metrics) == set(obs_metrics.FIELDS)


# --------------------------------------------------------------- sharded
def test_metrics_neutral_sharded_subprocess():
    """Mesh route with 8 forced host devices and uneven B: metrics rows
    shard/pad/slice with the instances and the states stay bitwise."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    body = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import aco, tsp
        from repro.solver import batch as batch_mod
        from repro.solver import engine, placement

        insts = [tsp.circle_instance(n, seed=n) for n in (10, 13, 12)]
        cfg = aco.ACOConfig(iterations=6, variant="mmas",
                            selection="gumbel")
        cfg_m = dataclasses.replace(cfg, metrics=True)
        b = batch_mod.make_batch(insts, 16, cfg.nn_k)
        budgets = jnp.asarray([6, 3, 5], jnp.int32)
        mesh = placement.data_mesh(8)     # B=3 over D=8: phantom padding

        def run(c):
            return engine.run_batch(
                b.problem, engine.init_states(insts, c, [1, 2, 3], 16),
                budgets, c, 6, mesh=mesh)

        ref = run(cfg)
        got = run(cfg_m)
        assert len(ref) == 2 and len(got) == 3
        for x, y in zip(jax.tree.leaves(ref), jax.tree.leaves(got[:2])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        mets = got[2]
        assert mets.best_len.shape == (3,)       # sliced back to B
        np.testing.assert_allclose(np.asarray(mets.best_len),
                                   np.asarray(got[0].best_len), rtol=1e-6)
        print("SHARDED OBS OK")
    """)
    out = subprocess.run([sys.executable, "-c", body], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "SHARDED OBS OK" in out.stdout
