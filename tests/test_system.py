"""End-to-end behaviour tests for the paper's system.

These validate the fidelity claims C1-C6 (DESIGN.md §1) at test scale and
check the public examples and the placement engine run."""
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aco, placement, sequential, strategies, tsp

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_c1_data_parallel_faster_than_task_parallel():
    """C1 (paper total-speedup form): the data-parallel construction beats
    the task-parallel *baseline* (per-ant roulette + per-step heuristic
    recompute — the paper's version 1). The narrower v2-vs-v7 GPU-thread
    granularity effect intentionally does not transfer to XLA (DESIGN.md §6:
    both variants vectorise over ants in a compiled-tensor runtime)."""
    from benchmarks.timing import time_fn
    n = 180
    inst = tsp.random_instance(n, seed=1)
    prob = aco.make_problem(inst, 10)
    tau = jnp.ones((n, n))
    ci = strategies.choice_matrix(tau, prob.eta, 1.0, 2.0)
    key = jax.random.PRNGKey(0)

    t_task = time_fn(lambda k: strategies.construct_tours(
        k, prob.dist, ci, n, method="task_baseline", tau=tau, eta=prob.eta),
        key, warmup=1, iters=3)
    t_data = time_fn(lambda k: strategies.construct_tours(
        k, prob.dist, ci, n, method="data_parallel"), key, warmup=1, iters=3)
    assert t_data < t_task, (t_data, t_task)


def test_c2_choice_precompute_faster_than_recompute():
    from benchmarks.timing import time_fn
    n = 120
    inst = tsp.random_instance(n, seed=2)
    prob = aco.make_problem(inst, 10)
    tau = jnp.ones((n, n))
    ci = strategies.choice_matrix(tau, prob.eta, 1.0, 2.0)
    key = jax.random.PRNGKey(0)
    t_base = time_fn(lambda k: strategies.construct_tours(
        k, prob.dist, ci, n, method="task_baseline", tau=tau, eta=prob.eta,
        alpha=1.0, beta=2.0), key, warmup=1, iters=3)
    t_choice = time_fn(lambda k: strategies.construct_tours(
        k, prob.dist, ci, n, method="task_choice"), key, warmup=1, iters=3)
    assert t_choice < t_base, (t_choice, t_base)


def test_c4_s2g_orders_of_magnitude_worse():
    """C4: scatter-to-gather deposit costs >> scatter, growing with n."""
    from benchmarks.timing import time_fn
    from repro.core import pheromone
    ratios = []
    for n in (64, 160):
        inst = tsp.random_instance(n, seed=3)
        prob = aco.make_problem(inst, 8)
        ci = strategies.choice_matrix(jnp.ones((n, n)), prob.eta, 1.0, 2.0)
        res = strategies.construct_tours(jax.random.PRNGKey(1), prob.dist,
                                         ci, n)
        w = 1.0 / res.lengths
        tau = jnp.ones((n, n))
        t_sc = time_fn(jax.jit(lambda t: pheromone.update(
            t, res.tours, w, 0.5, "scatter")), tau, warmup=1, iters=3)
        t_s2g = time_fn(jax.jit(lambda t: pheromone.update(
            t, res.tours, w, 0.5, "s2g")), tau, warmup=1, iters=3)
        ratios.append(t_s2g / t_sc)
    # assert at the larger size: at n=64 the scatter baseline is dispatch-
    # overhead dominated and the ratio is unstable under a warm process.
    assert ratios[-1] > 3.0, ratios         # orders of magnitude at scale
    assert ratios[1] > ratios[0], ratios    # grows with n


def test_c6_quality_parity_with_sequential():
    """C6: parallel variants reach the same solution quality as the
    sequential code on a known-optimum instance."""
    inst = tsp.circle_instance(36, seed=4)
    seq = sequential.SequentialAS(inst.distances(), m=36, seed=1)
    seq.run(40)
    seq_gap = seq.best_len / inst.known_optimum - 1
    st = aco.run(inst, aco.ACOConfig(iterations=40))
    par_gap = float(st.best_len) / inst.known_optimum - 1
    assert abs(par_gap - seq_gap) < 0.05
    assert par_gap < 0.05


def test_placement_engine_beats_uniform_on_heterogeneous():
    rng = np.random.RandomState(1)
    costs = np.exp(rng.normal(0, 1.0, size=32)) * 10
    prob = placement.PlacementProblem(
        layer_costs=tuple(costs), edge_traffic=(1.0,) * 32, n_stages=4,
        comm_lambda=0.02)
    _, uni = placement.uniform_baseline(prob)
    _, ours = placement.solve(prob, placement.PlacementConfig(
        ants=32, iterations=40, seed=0))
    assert ours < uni


@pytest.mark.parametrize("script", ["quickstart.py", "aco_placement.py"])
def test_examples_run(script):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__), "..",
                                      "examples", script)],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
