"""Per-architecture smoke tests (task requirement): every assigned arch in
its REDUCED form runs one forward + one train step + 2 decode steps on CPU,
asserting output shapes and finiteness. The FULL configs are exercised only
by the dry-run (no allocation)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import steps as st
from repro.models import model
from repro.optim import adamw

KEY = jax.random.PRNGKey(1234)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke(arch):
    cfg = configs.get_reduced(arch)
    params = model.init_params(jax.random.fold_in(KEY, hash(arch) % 997), cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0,
                              cfg.vocab)
    labels = jnp.roll(toks, -1, axis=1)
    enc = None
    if cfg.enc_dec:
        enc = jax.random.normal(jax.random.fold_in(KEY, 2),
                                (B, 8, cfg.d_model), jnp.float32)

    # forward
    logits, aux = model.forward(params, toks, cfg, enc_frames=enc)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # one train step
    opt_cfg = adamw.AdamWConfig(total_steps=10, warmup_steps=1)
    step = st.make_train_step(cfg, opt_cfg, remat=True)
    new_params, opt2, metrics = step(params, adamw.adamw_init(params),
                                     toks, labels, enc)
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt2.step) == 1
    # params actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params))
    assert max(moved) > 0

    # decode 2 steps
    caches = model.init_cache(cfg, B, 24, enc_len=8 if cfg.enc_dec else 0)
    if cfg.enc_dec:
        enc_out = model.encode(params, enc, cfg)
        caches = model.fill_cross_caches(params, caches, enc_out, cfg)
    serve = st.make_serve_step(cfg)
    tok = toks[:, :1]
    for _ in range(2):
        tok, caches = serve(params, tok, caches)
        assert tok.shape == (B, 1)
        assert int(tok.min()) >= 0 and int(tok.max()) < cfg.vocab
    assert int(caches["step"]) == 2


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_instantiates_abstractly(arch):
    """FULL configs must build abstract param/optimizer trees (no memory)."""
    from repro.launch import specs as sp
    cfg = configs.get(arch)
    params_abs = sp.abstract_params(cfg)
    n_leaves = len(jax.tree.leaves(params_abs))
    assert n_leaves > 4
    # analytic vs abstract param count agreement (<0.5% — analytic skips
    # norm vectors and biases)
    abstract_n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_abs))
    analytic_n = cfg.param_count()
    assert abs(abstract_n - analytic_n) / analytic_n < 5e-3, (
        arch, abstract_n, analytic_n)


def test_train_loss_decreases_end_to_end(tmp_path):
    """~30 steps of the real trainer on a tiny model must cut the loss."""
    from repro.launch.train import train
    out = train("olmo_1b", steps=40, batch=4, seq=64, reduced=True,
                ckpt_dir=str(tmp_path), ckpt_every=20, log_every=5,
                lr=3e-3)
    assert out["losses"][0] > out["final_loss"], out["losses"]
    assert out["final_loss"] < out["losses"][0] * 0.9


def test_train_restart_resumes(tmp_path):
    from repro.launch.train import train
    train("olmo_1b", steps=10, batch=2, seq=32, reduced=True,
          ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    # second call resumes from step 10 checkpoint and extends to 12
    out = train("olmo_1b", steps=12, batch=2, seq=32, reduced=True,
                ckpt_dir=str(tmp_path), ckpt_every=5, log_every=100)
    assert out["final_loss"] is not None


def test_serve_end_to_end():
    from repro.launch.serve import serve
    out = serve("qwen2_vl_2b", batch=2, prompt_len=8, gen=6, reduced=True)
    arr = np.asarray(out["tokens"])
    assert arr.shape == (2, 6)
    assert out["decode_s_per_token"] > 0
