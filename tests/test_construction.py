"""Construction hot-path overhaul tests (DESIGN.md §10).

Four claims:

1. The fused choice->select kernel (kernels/fused_select.py) matches its
   pure-jnp oracle (kernels/ref.py) bitwise across odd shapes,
   non-divisible block sizes, and masked (n_actual < n) instances.
2. Kernel route == pure-JAX route through ``colony_step``: constructed
   tours/lengths are bitwise equal for AS/MMAS/ACS, masked and unmasked;
   full ColonyState (tau included) is bitwise for single-deposit updates
   (MMAS, ACS, AS with one ant) — AS with many ants differs in deposit
   summation order by design, asserted to ulp tolerance.
3. The lazy NN fallback (count-gated lax.cond) is bitwise identical to the
   pre-overhaul eager fallback registered as ``nn_list_eager``.
4. ``run_batch(donate=True)`` returns the same results as the non-donating
   route, and ``check_kernel_route`` enforces the support matrix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aco, strategies, tsp
from repro.kernels import fused_select as fs_k
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.solver import batch as batch_mod
from repro.solver import engine, streaming

KEY = jax.random.PRNGKey(7)


# ------------------------------------------------------------ fused kernel
def _fused_case(m, n, mode, alpha=1.0, beta=2.0, n_actual=None,
                block_m=8, block_n=512, seed=0):
    k = jax.random.fold_in(KEY, seed * 7919 + m * 31 + n)
    tau = jax.random.uniform(k, (n, n)) + 0.1
    eta = jax.random.uniform(jax.random.fold_in(k, 1), (n, n)) + 0.1
    hi = n if n_actual is None else int(n_actual)
    if n_actual is not None:
        # padded-instance invariant: phantom eta is exactly 0
        eta = eta.at[:, hi:].set(0.0).at[hi:, :].set(0.0)
    cur = jax.random.randint(jax.random.fold_in(k, 2), (m,), 0, hi)
    vis = jax.random.uniform(jax.random.fold_in(k, 3), (m, n)) < 0.5
    vis = vis.at[:, 0].set(False)
    rand = jax.random.uniform(jax.random.fold_in(k, 4), (m, n),
                              minval=1e-6, maxval=1.0)
    na = None if n_actual is None else jnp.int32(n_actual)
    got = fs_k.fused_select(tau, eta, cur, vis, rand, alpha, beta, na, mode,
                            block_m=block_m, block_n=block_n, interpret=True)
    exp = ref.fused_select(tau, eta, cur, vis.astype(jnp.int8), rand,
                           alpha, beta, na, mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    if n_actual is not None:
        assert (np.asarray(got) < hi).all(), "phantom city selected"


@pytest.mark.parametrize("mode", ["iroulette", "gumbel", "greedy"])
@pytest.mark.parametrize("m,n", [(1, 7), (5, 48), (16, 513), (3, 130)])
def test_fused_select_matches_ref(mode, m, n):
    _fused_case(m, n, mode)


@pytest.mark.parametrize("alpha,beta", [(1.0, 2.0), (2.0, 3.0), (0.5, 2.5)])
def test_fused_select_exponents(alpha, beta):
    _fused_case(9, 100, "iroulette", alpha=alpha, beta=beta)


@pytest.mark.parametrize("block_m,block_n", [(3, 60), (8, 128), (16, 37),
                                             (5, 512)])
def test_fused_select_block_invariance(block_m, block_n):
    """Tiling (incl. non-divisible blocks) must not change the selection."""
    _fused_case(13, 259, "iroulette", block_m=block_m, block_n=block_n)
    _fused_case(13, 259, "greedy", block_m=block_m, block_n=block_n,
                n_actual=197)


@pytest.mark.parametrize("mode", ["iroulette", "gumbel", "greedy"])
@pytest.mark.parametrize("n,n_actual", [(64, 64), (64, 41), (513, 400),
                                        (130, 97)])
def test_fused_select_masked(mode, n, n_actual):
    _fused_case(11, n, mode, n_actual=n_actual)


def test_tour_select_masked_matches_ref():
    m, n, na = 9, 130, 97
    k = jax.random.fold_in(KEY, 55)
    rows = jax.random.uniform(k, (m, n)) + 0.01
    vis = jax.random.uniform(jax.random.fold_in(k, 1), (m, n)) < 0.5
    vis = vis.at[:, 0].set(False)
    rand = jax.random.uniform(jax.random.fold_in(k, 2), (m, n),
                              minval=1e-6, maxval=1.0)
    for mode in ("iroulette", "gumbel", "greedy"):
        got = kops.tour_select(rows, vis, rand, mode, jnp.int32(na))
        exp = ref.tour_select(rows, vis.astype(jnp.int8), rand, mode,
                              jnp.int32(na))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
        assert (np.asarray(got) < na).all()


def test_choice_info_masked_zeroes_phantoms():
    n, na = 100, 67
    k = jax.random.fold_in(KEY, 66)
    tau = jax.random.uniform(k, (n, n)) + 0.1
    eta = jax.random.uniform(jax.random.fold_in(k, 1), (n, n)) + 0.1
    got = np.asarray(kops.choice_info(tau, eta, 1.0, 2.0, jnp.int32(na)))
    exp = np.array(ref.choice_info(tau, eta, 1.0, 2.0))
    exp[na:, :] = 0.0
    exp[:, na:] = 0.0
    np.testing.assert_array_equal(got, exp)


def test_pheromone_update_masked_matches_scatter():
    """Masked kernel deposit == masked pure-JAX scatter: phantom-tail edges
    are weight-0 and the closing edge wraps at n_actual-1."""
    from repro.core import pheromone
    n, na, m = 48, 37, 5
    k = jax.random.fold_in(KEY, 77)
    tours = jnp.stack([
        jnp.concatenate([jax.random.permutation(jax.random.fold_in(k, i), na),
                         jnp.arange(na, n)])
        for i in range(m)
    ]).astype(jnp.int32)
    w = jax.random.uniform(jax.random.fold_in(k, 9), (m,)) + 0.1
    tau = jax.random.uniform(jax.random.fold_in(k, 10), (n, n)) + 0.5
    got = kops.pheromone_update(tau, tours, w, 0.5, n_actual=jnp.int32(na))
    exp = pheromone.update(tau, tours, w, 0.5, strategy="scatter",
                           n_actual=jnp.int32(na))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
    # phantom block must be pure evaporation: no deposit leaked
    np.testing.assert_array_equal(np.asarray(got)[na:, na:],
                                  np.asarray(0.5 * tau)[na:, na:])


# ------------------------------------------------- kernel route == JAX route
def _state_diff(a: aco.ColonyState, b: aco.ColonyState):
    tours_eq = np.array_equal(np.asarray(a.best_tour), np.asarray(b.best_tour))
    len_eq = np.array_equal(np.asarray(a.best_len), np.asarray(b.best_len))
    tau_eq = np.array_equal(np.asarray(a.tau), np.asarray(b.tau))
    return tours_eq, len_eq, tau_eq


@pytest.mark.parametrize("variant,full_bitwise", [
    ("as", False),     # m ants deposit: summation order differs by design
    ("mmas", True),    # single-tour deposit: every cell gets <= 1 deposit
    ("acs", False),    # shared post-deposit math fuses differently (ulp)
])
def test_kernel_route_equals_jax_route(variant, full_bitwise):
    """use_pallas=True (fused construction + kernel deposit) against the
    pure-JAX route through real colony_step iterations: constructed tours
    and best lengths are bitwise equal always; tau is bitwise where the
    deposit is single-hit per cell (DESIGN.md §10), ulp-close otherwise."""
    inst = tsp.circle_instance(49, seed=3)
    prob = aco.make_problem(inst, nn_k=10)
    kw = dict(iterations=4, variant=variant, selection="iroulette", seed=1)
    cfg_j = aco.ACOConfig(use_pallas=False, **kw)
    cfg_k = aco.ACOConfig(use_pallas=True, **kw)
    sj = aco.init_colony(inst, cfg_j)
    sk = aco.init_colony(inst, cfg_k)
    for _ in range(3):
        sj, _ = aco.colony_step(prob, sj, cfg_j)
        sk, _ = aco.colony_step(prob, sk, cfg_k)
        tours_eq, len_eq, tau_eq = _state_diff(sj, sk)
        assert tours_eq and len_eq
        if full_bitwise:
            assert tau_eq
        else:
            np.testing.assert_allclose(np.asarray(sj.tau), np.asarray(sk.tau),
                                       rtol=1e-5, atol=1e-7)


def test_kernel_route_as_single_ant_full_bitwise():
    """One ant -> one tour -> no duplicate deposit edges -> the AS kernel
    route is fully bitwise too."""
    inst = tsp.circle_instance(40, seed=4)
    prob = aco.make_problem(inst, nn_k=8)
    cfg_j = aco.ACOConfig(iterations=4, m=1, seed=2, use_pallas=False)
    cfg_k = aco.ACOConfig(iterations=4, m=1, seed=2, use_pallas=True)
    sj = aco.init_colony(inst, cfg_j)
    sk = aco.init_colony(inst, cfg_k)
    for _ in range(3):
        sj, _ = aco.colony_step(prob, sj, cfg_j)
        sk, _ = aco.colony_step(prob, sk, cfg_k)
    assert all(_state_diff(sj, sk))


def test_fused_construction_bitwise_vs_dense():
    """construct_tours: fused kernel method == data_parallel method,
    bitwise, same PRNG stream (tie semantics included)."""
    inst = tsp.random_instance(73, seed=9)          # odd n: non-divisible
    prob = aco.make_problem(inst, nn_k=10)
    tau = jnp.full((73, 73), 0.7)
    key = jax.random.fold_in(KEY, 3)
    ci = strategies.choice_matrix(tau, prob.eta, 1.0, 2.0)
    for sel in ("iroulette", "greedy"):
        rj = strategies.construct_tours(key, prob.dist, ci, 20,
                                        method="data_parallel", selection=sel,
                                        tau=tau, eta=prob.eta)
        rk = strategies.construct_tours(key, prob.dist, jnp.zeros((1, 1)), 20,
                                        method="fused", selection=sel,
                                        tau=tau, eta=prob.eta)
        np.testing.assert_array_equal(np.asarray(rj.tours),
                                      np.asarray(rk.tours))
        np.testing.assert_array_equal(np.asarray(rj.lengths),
                                      np.asarray(rk.lengths))


@pytest.mark.parametrize("variant", ["as", "mmas", "acs"])
def test_masked_kernel_route_matches_pure_and_solo(variant):
    """Padded instances through the batched engine with use_pallas=True:
    tours/lengths match the pure-JAX masked route bitwise, and batched ==
    solo composition holds on the kernel route."""
    insts = [tsp.circle_instance(n, seed=i)
             for i, n in enumerate((13, 20, 29))]
    kw = dict(iterations=5, variant=variant, selection="iroulette")
    cfg_k = aco.ACOConfig(use_pallas=True, **kw)
    cfg_j = aco.ACOConfig(use_pallas=False, **kw)
    st_k, bk = engine.solve_instances(insts, cfg_k, n_pad=32)
    st_j, _ = engine.solve_instances(insts, cfg_j, n_pad=32)
    np.testing.assert_array_equal(np.asarray(st_k.best_tour),
                                  np.asarray(st_j.best_tour))
    np.testing.assert_array_equal(np.asarray(st_k.best_len),
                                  np.asarray(st_j.best_len))
    for r in engine.collect(st_k, bk):
        assert tsp.is_valid_tour(np.asarray(r["best_tour"]))
    # batched == solo on the kernel route (default per-index seeds: cfg.seed+i)
    solo, _ = engine.solve_instances([insts[1]], cfg_k, n_pad=32,
                                     seeds=[cfg_k.seed + 1])
    assert float(solo.best_len[0]) == float(st_k.best_len[1])
    np.testing.assert_array_equal(np.asarray(solo.best_tour[0]),
                                  np.asarray(st_k.best_tour[1]))


def test_streaming_pallas_matches_solo():
    """StreamingSolverService now composes with use_pallas=True."""
    cfg = aco.ACOConfig(iterations=6, use_pallas=True)
    svc = streaming.StreamingSolverService(cfg, max_batch=2, chunk=3)
    sizes = (14, 21, 18)
    for i, n in enumerate(sizes):
        svc.submit(tsp.circle_instance(n, seed=i), seed=i)
    res = {r.request_id: r for r in svc.run_until_drained()}
    assert len(res) == 3
    for i, n in enumerate(sizes):
        st, _ = engine.solve_instances([tsp.circle_instance(n, seed=i)],
                                       cfg, n_pad=res[i].bucket, seeds=[i])
        assert float(st.best_len[0]) == res[i].best_len


# ------------------------------------------------------- lazy NN fallback
@pytest.mark.parametrize("kind", ["circle", "random"])
def test_lazy_nn_fallback_bitwise_equals_eager(kind):
    """The count-gated lax.cond fallback must be unobservable in output:
    nn_list == nn_list_eager bitwise (the fallback branch value is only
    consumed where a candidate set is exhausted)."""
    make = tsp.circle_instance if kind == "circle" else tsp.random_instance
    inst = make(61, seed=11)
    prob = aco.make_problem(inst, nn_k=6)     # tiny k: fallback fires often
    tau = jnp.full((61, 61), 0.4)
    ci = strategies.choice_matrix(tau, prob.eta, 1.0, 2.0)
    key = jax.random.fold_in(KEY, 13)
    a = strategies.construct_tours(key, prob.dist, ci, 61, method="nn_list",
                                   selection="iroulette", nn=prob.nn)
    b = strategies.construct_tours(key, prob.dist, ci, 61,
                                   method="nn_list_eager",
                                   selection="iroulette", nn=prob.nn)
    np.testing.assert_array_equal(np.asarray(a.tours), np.asarray(b.tours))
    np.testing.assert_array_equal(np.asarray(a.lengths),
                                  np.asarray(b.lengths))


def test_lazy_nn_fallback_under_vmap():
    """Under vmap the cond lowers to select (both branches run) — results
    must still match the solo lazy route bitwise."""
    insts = [tsp.circle_instance(n, seed=i) for i, n in enumerate((17, 23))]
    cfg = aco.ACOConfig(iterations=4, construction="nn_list", nn_k=5)
    st, b = engine.solve_instances(insts, cfg, n_pad=32)
    solo, _ = engine.solve_instances([insts[0]], cfg, n_pad=32,
                                     seeds=[cfg.seed])
    assert float(solo.best_len[0]) == float(st.best_len[0])


# ------------------------------------------------- donation + support matrix
def test_run_batch_donate_matches_non_donating():
    insts = [tsp.circle_instance(n, seed=i) for i, n in enumerate((12, 18))]
    cfg = aco.ACOConfig(iterations=5)
    b = batch_mod.make_batch(insts, 32, 10)
    budgets = jnp.asarray([5, 3], jnp.int32)
    r0, s0 = engine.run_batch(b.problem,
                              engine.init_states(insts, cfg, [0, 1], 32),
                              budgets, cfg, 5, patience=2)
    r1, s1 = engine.run_batch(b.problem,
                              engine.init_states(insts, cfg, [0, 1], 32),
                              budgets, cfg, 5, patience=2, donate=True)
    for x, y in zip(jax.tree.leaves(r0), jax.tree.leaves(r1)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_kernel_route_support_matrix():
    """check_kernel_route: masked is supported, Hyper operands are not —
    and the rejection is one typed error everywhere it surfaces."""
    kops.check_kernel_route()                      # plain: fine
    kops.check_kernel_route(masked=True)           # padded instances: fine
    with pytest.raises(kops.UnsupportedKernelRoute, match="Hyper"):
        kops.check_kernel_route(hyper=True)
    assert issubclass(kops.UnsupportedKernelRoute, NotImplementedError)
    # colony_step surfaces it for hyper-carrying problems on the kernel route
    inst = tsp.circle_instance(16, seed=0)
    cfg = aco.ACOConfig(iterations=2, use_pallas=True)
    prob = aco.make_problem(inst, 5)._replace(hyper=aco.Hyper.make(cfg))
    with pytest.raises(kops.UnsupportedKernelRoute, match="Hyper"):
        aco.colony_step(prob, aco.init_colony(inst, cfg), cfg)
    # the fused construction method rejects genuinely *traced* exponents
    # the same way...
    def build(a):
        return strategies.construct_tours(
            KEY, prob.dist, jnp.zeros((1, 1)), 4, method="fused",
            tau=jnp.ones((16, 16)), eta=prob.eta,
            alpha=a, beta=2.0).lengths
    with pytest.raises(kops.UnsupportedKernelRoute, match="static"):
        jax.jit(build)(jnp.float32(1.5))
    # ...but any concrete scalar (python, numpy, or jax) is static-able
    for a in (1.5, np.float32(1.5), jnp.float32(1.5)):
        assert build(a).shape == (4,)
