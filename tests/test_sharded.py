"""Placement-layer tests (DESIGN.md §11): sharded == single-device bitwise.

Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test
session keeps seeing exactly 1 device (the dry-run isolation rule, same
pattern as tests/test_distributed.py).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aco, tsp
from repro.solver import batch as batch_mod
from repro.solver import engine, placement

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(body: str, xla_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={xla_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ------------------------------------------------------------- in-process
def test_pad_to_devices_phantom_slots():
    """Uneven batches gain replicated row-0 phantom slots with budget 0,
    and even batches pass through untouched."""
    insts = [tsp.circle_instance(n, seed=n) for n in (10, 12, 14)]
    cfg = aco.ACOConfig()
    b = batch_mod.make_batch(insts, 16, cfg.nn_k)
    states = engine.init_states(insts, cfg, [1, 2, 3], 16)
    budgets = jnp.asarray([5, 6, 7], jnp.int32)
    since = jnp.zeros_like(budgets)

    p, s, bud, sin, mets, orig = placement.pad_to_devices(
        b.problem, states, budgets, since, 4)
    assert orig == 3 and mets is None          # metrics off: no rows
    assert bud.shape == (4,) and sin.shape == (4,)
    assert int(bud[3]) == 0                     # phantom: already done
    np.testing.assert_array_equal(np.asarray(p.dist[3]),
                                  np.asarray(p.dist[0]))
    np.testing.assert_array_equal(np.asarray(s.tau[3]),
                                  np.asarray(s.tau[0]))

    p2, s2, bud2, _, _, orig2 = placement.pad_to_devices(
        b.problem, states, budgets, since, 3)
    assert orig2 == 3 and bud2.shape == (3,)
    assert p2 is b.problem and s2 is states    # no-op when B % D == 0


def test_pad_to_devices_quantised_leaves():
    """Phantom padding replicates the quantised payload/scale leaves like
    any other state leaf — row 0's int8 bits and per-row scales appear in
    the phantom slot untouched."""
    insts = [tsp.circle_instance(n, seed=n) for n in (10, 12, 14)]
    cfg = aco.ACOConfig(tau_dtype="int8")
    b = batch_mod.make_batch(insts, 16, cfg.nn_k)
    states = engine.init_states(insts, cfg, [1, 2, 3], 16)
    budgets = jnp.asarray([5, 6, 7], jnp.int32)
    since = jnp.zeros_like(budgets)
    _, s, bud, _, _, orig = placement.pad_to_devices(
        b.problem, states, budgets, since, 4)
    assert orig == 3 and int(bud[3]) == 0
    assert s.tau.q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(s.tau.q[3]),
                                  np.asarray(s.tau.q[0]))
    np.testing.assert_array_equal(np.asarray(s.tau.scale[3]),
                                  np.asarray(s.tau.scale[0]))
    assert s.tau.err.shape == (4, 16, 0)        # zero-width leaf padded too


def test_sharded_one_device_mesh_bitwise_quantised():
    """Quantised ColonyState leaves shard and gather like fp32 ones: the
    D=1 mesh route is bitwise the plain route on every leaf."""
    insts = [tsp.circle_instance(n, seed=n) for n in (10, 13, 12)]
    cfg = aco.ACOConfig(iterations=6, selection="gumbel", tau_dtype="int8")
    b = batch_mod.make_batch(insts, 16, cfg.nn_k)
    budgets = jnp.asarray([6, 3, 5], jnp.int32)
    ref, ref_since = engine.run_batch(
        b.problem, engine.init_states(insts, cfg, [1, 2, 3], 16),
        budgets, cfg, 6, patience=2)
    got, got_since = engine.run_batch(
        b.problem, engine.init_states(insts, cfg, [1, 2, 3], 16),
        budgets, cfg, 6, patience=2, mesh=placement.data_mesh(1))
    for a, c in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    np.testing.assert_array_equal(np.asarray(ref_since),
                                  np.asarray(got_since))


def test_data_mesh_bounds():
    with pytest.raises(ValueError, match="devices"):
        placement.data_mesh(99)
    with pytest.raises(ValueError, match="devices"):
        placement.data_mesh(0)
    assert placement.data_mesh(1).shape["data"] == 1


def test_sharded_one_device_mesh_bitwise():
    """The mesh route with D=1 (the only topology the main session can
    build) is bitwise the plain route, uneven-B padding included."""
    insts = [tsp.circle_instance(n, seed=n) for n in (10, 13, 12)]
    cfg = aco.ACOConfig(iterations=6, selection="gumbel")
    b = batch_mod.make_batch(insts, 16, cfg.nn_k)
    budgets = jnp.asarray([6, 3, 5], jnp.int32)
    ref, ref_since = engine.run_batch(
        b.problem, engine.init_states(insts, cfg, [1, 2, 3], 16),
        budgets, cfg, 6, patience=2)
    got, got_since = engine.run_batch(
        b.problem, engine.init_states(insts, cfg, [1, 2, 3], 16),
        budgets, cfg, 6, patience=2, mesh=placement.data_mesh(1))
    for a, c in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    np.testing.assert_array_equal(np.asarray(ref_since),
                                  np.asarray(got_since))


def test_run_batch_rejects_unknown_mesh_axis():
    insts = [tsp.circle_instance(10, seed=0)]
    cfg = aco.ACOConfig(iterations=2)
    b = batch_mod.make_batch(insts, 16, cfg.nn_k)
    with pytest.raises(ValueError, match="no axis"):
        engine.run_batch(b.problem,
                         engine.init_states(insts, cfg, [1], 16),
                         jnp.asarray([2], jnp.int32), cfg, 2,
                         mesh=placement.data_mesh(1),
                         instance_spec="model")


# ---------------------------------------------------- subprocess, 8 devices
def test_sharded_run_batch_bitwise_parity_8dev():
    """Sharded run_batch == single-device run_batch bitwise per instance:
    AS/MMAS/ACS, uneven B % D, per-instance budgets, D in {1, 2, 8},
    donated buffers."""
    _run_subprocess("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import aco, tsp
        from repro.solver import batch as bm, engine, placement
        assert len(jax.devices()) == 8, jax.devices()

        insts = [tsp.circle_instance(n, seed=n) if k % 2 == 0
                 else tsp.random_instance(n, seed=n)
                 for k, n in enumerate((10, 13, 12, 15, 11))]
        budgets = jnp.asarray([6, 3, 5, 2, 7], jnp.int32)  # per-instance
        for variant in ("as", "mmas", "acs"):
            cfg = aco.ACOConfig(iterations=7, variant=variant,
                                selection="gumbel")
            b = bm.make_batch(insts, 16, cfg.nn_k)
            seeds = [40 + i for i in range(5)]
            ref, ref_since = engine.run_batch(
                b.problem, engine.init_states(insts, cfg, seeds, 16),
                budgets, cfg, 7, patience=3)
            for d in (1, 2, 8):              # 5 % 2 and 5 % 8 both uneven
                for donate in (False, True):
                    got, got_since = engine.run_batch(
                        b.problem,
                        engine.init_states(insts, cfg, seeds, 16),
                        budgets, cfg, 7, patience=3,
                        mesh=placement.data_mesh(d), donate=donate)
                    for a, c in zip(ref, got):
                        np.testing.assert_array_equal(
                            np.asarray(a), np.asarray(c),
                            err_msg=f"{variant} D={d} donate={donate}")
                    np.testing.assert_array_equal(
                        np.asarray(ref_since), np.asarray(got_since))
        print("PARITY OK")
    """)


def test_sharded_quantised_run_batch_bitwise_8dev():
    """Quantised (int8 + per-row scales, bf16) slot stacks shard across
    8 devices and come back bitwise the single-device run on every leaf —
    the QuantTau payload/scale/err leaves ride placement like any other
    state leaf, uneven B % D padding included."""
    _run_subprocess("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import aco, tsp
        from repro.solver import batch as bm, engine, placement
        assert len(jax.devices()) == 8, jax.devices()

        insts = [tsp.circle_instance(n, seed=n) if k % 2 == 0
                 else tsp.random_instance(n, seed=n)
                 for k, n in enumerate((10, 13, 12, 15, 11))]
        budgets = jnp.asarray([6, 3, 5, 2, 7], jnp.int32)
        seeds = [40 + i for i in range(5)]
        for tau_dtype in ("int8", "bf16"):
            cfg = aco.ACOConfig(iterations=7, variant="mmas",
                                selection="gumbel", tau_dtype=tau_dtype)
            b = bm.make_batch(insts, 16, cfg.nn_k)
            ref, ref_since = engine.run_batch(
                b.problem, engine.init_states(insts, cfg, seeds, 16),
                budgets, cfg, 7, patience=3)
            assert jax.tree.leaves(ref.tau)[0].dtype == (
                jnp.int8 if tau_dtype == "int8" else jnp.bfloat16)
            for d in (2, 8):                 # both uneven: 5 % d != 0
                got, got_since = engine.run_batch(
                    b.problem,
                    engine.init_states(insts, cfg, seeds, 16),
                    budgets, cfg, 7, patience=3,
                    mesh=placement.data_mesh(d))
                for a, c in zip(jax.tree.leaves(ref),
                                jax.tree.leaves(got)):
                    a, c = np.asarray(a), np.asarray(c)
                    if a.dtype == jnp.bfloat16:
                        a = a.view(np.uint16); c = c.view(np.uint16)
                    np.testing.assert_array_equal(
                        a, c, err_msg=f"{tau_dtype} D={d}")
                np.testing.assert_array_equal(
                    np.asarray(ref_since), np.asarray(got_since))
        print("QUANT PARITY OK")
    """)


def test_service_sharded_matches_unsharded_8dev():
    """SolverService with a mesh returns bitwise the unsharded service's
    results (multi-bucket workload, uneven counts per bucket)."""
    _run_subprocess("""
        import numpy as np
        from repro.core import aco, tsp
        from repro.solver import SolverService, placement
        insts = [tsp.circle_instance(n, seed=n)
                 for n in (10, 14, 12, 20, 26, 11, 24)]
        cfg = aco.ACOConfig(iterations=5, selection="gumbel")
        def run(mesh):
            svc = SolverService(cfg, max_batch=4, mesh=mesh)
            for k, inst in enumerate(insts):
                svc.submit(inst, iterations=3 + (k % 3), seed=60 + k)
            return svc.run(), svc.stats
        ref, _ = run(None)
        got, stats = run(placement.data_mesh(8))
        assert stats["devices"] == 8, stats
        for a, c in zip(ref, got):
            assert a.request_id == c.request_id
            assert a.best_len == c.best_len, a.request_id
            np.testing.assert_array_equal(a.best_tour, c.best_tour)
            assert a.iterations == c.iterations
        print("SERVICE OK")
    """)


def test_streaming_per_device_pools_match_single_pool_8dev():
    """StreamingSolverService with per-device pools returns bitwise the
    single-pool results on the same admission order, and actually spreads
    the work over multiple pools."""
    _run_subprocess("""
        import numpy as np
        from repro.core import aco, tsp
        from repro.solver import StreamingSolverService, placement
        insts = [tsp.circle_instance(n, seed=n) if k % 2 == 0
                 else tsp.random_instance(n, seed=n)
                 for k, n in enumerate((10, 13, 12, 14, 11, 15, 16, 13))]
        buds = (6, 3, 7, 4, 5, 6, 2, 4)
        cfg = aco.ACOConfig(iterations=8, selection="gumbel")
        def run(mesh):
            svc = StreamingSolverService(cfg, max_batch=2, min_bucket=16,
                                         chunk=2, mesh=mesh)
            for k, inst in enumerate(insts):
                svc.submit(inst, iterations=buds[k], seed=80 + k)
            return ({r.request_id: r for r in svc.run_until_drained()},
                    svc.stats)
        ref, _ = run(None)
        got, stats = run(placement.data_mesh(4))
        assert stats["devices"] == 4 and stats["pools"] == 4, stats
        # least-occupied routing really spread the first wave over pools
        assert stats["fills"] == len(insts)
        for k in ref:
            assert ref[k].best_len == got[k].best_len, k
            np.testing.assert_array_equal(ref[k].best_tour,
                                          got[k].best_tour)
            assert ref[k].iterations == got[k].iterations
        print("STREAM OK")
    """)


# ------------------------------------------------------- solve_serve CLI
def test_solve_serve_unsupported_kernel_route_one_liner():
    """--use-pallas + --per-instance-hyper exits 2 with one actionable
    line on stderr, not a traceback."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.solve_serve", "--stream",
         "--use-pallas", "--per-instance-hyper", "--num-instances", "2",
         "--iterations", "2"],
        capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 2
    err = out.stderr.strip().splitlines()
    assert len(err) == 1, out.stderr
    # the one-liner relays the route checker's actionable message
    assert "Hyper" in err[0] and "use_pallas" in err[0]
    assert "Traceback" not in out.stderr
