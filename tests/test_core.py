"""ACO core behaviour tests: construction validity, deposit equivalence,
selection semantics, config plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyputil import given, settings, hst

from repro.core import aco, pheromone, sampling, strategies, tsp

KEY = jax.random.PRNGKey(7)


# ------------------------------------------------------------------- tsp
def test_distance_rounding_modes():
    inst_raw = tsp.random_instance(16, seed=0)
    d = inst_raw.distances()
    assert d.shape == (16, 16)
    assert np.allclose(d, d.T)
    assert (np.diag(d) == 0).all()

    coords = np.array([[0.0, 0.0], [3.0, 4.0]])
    euc = tsp.TSPInstance("t", coords, "EUC_2D").distances()
    assert euc[0, 1] == 5.0
    att = tsp.TSPInstance("t", coords, "ATT").distances()
    # pseudo-Euclidean: ceil-ish of sqrt(25/10)=1.58 -> 2
    assert att[0, 1] == 2.0


def test_nn_lists_are_nearest():
    inst = tsp.random_instance(50, seed=1)
    d = jnp.asarray(inst.distances())
    nn = np.asarray(tsp.nn_lists(d, 10))
    dn = np.asarray(d)
    for i in range(50):
        claimed = dn[i, nn[i]]
        others = np.delete(dn[i], np.concatenate([[i], nn[i]]))
        assert claimed.max() <= others.min() + 1e-6
        assert i not in nn[i]


def test_tour_length_matches_numpy():
    inst = tsp.random_instance(30, seed=2)
    d = inst.distances()
    tour = np.random.RandomState(0).permutation(30).astype(np.int32)
    expected = d[tour, np.roll(tour, -1)].sum()
    got = tsp.tour_length(jnp.asarray(d), jnp.asarray(tour))
    np.testing.assert_allclose(got, expected, rtol=1e-6)


def test_parse_tsplib_roundtrip():
    text = """NAME : toy
EDGE_WEIGHT_TYPE : EUC_2D
NODE_COORD_SECTION
1 0.0 0.0
2 3.0 4.0
3 6.0 8.0
EOF
"""
    inst = tsp.parse_tsplib(text)
    assert inst.name == "toy"
    assert inst.n == 3
    assert inst.distances()[0, 1] == 5.0


def _tsplib_text(ewt: str, coords=((0.0, 0.0), (3.0, 4.0), (10.0, 11.0))) -> str:
    rows = "\n".join(f"{i + 1} {x} {y}" for i, (x, y) in enumerate(coords))
    return (f"NAME : toy\nEDGE_WEIGHT_TYPE : {ewt}\n"
            f"NODE_COORD_SECTION\n{rows}\nEOF\n")


def test_parse_tsplib_att_pseudo_euclidean():
    inst = tsp.parse_tsplib(_tsplib_text("ATT"))
    assert inst.edge_weight_type == "ATT"
    d = inst.distances()
    # rij = sqrt(25/10) = 1.5811; tij = round = 2 >= rij -> 2 (no bump)
    assert d[0, 1] == 2.0
    # (7, 7): rij = sqrt(98/10) = 3.1305; tij = 3 < rij -> 3 + 1 = 4
    assert d[1, 2] == 4.0
    assert np.allclose(d, d.T) and (np.diag(d) == 0).all()


def test_parse_tsplib_ceil_2d_rounding():
    coords = ((0.0, 0.0), (3.0, 4.0), (10.0, 0.0))
    d = tsp.parse_tsplib(_tsplib_text("CEIL_2D", coords)).distances()
    euc = tsp.parse_tsplib(_tsplib_text("EUC_2D", coords)).distances()
    assert d[0, 1] == 5.0 and euc[0, 1] == 5.0   # exact distances agree
    # sqrt(65) = 8.062: CEIL_2D rounds up to 9, EUC_2D nint gives 8
    assert d[1, 2] == 9.0
    assert euc[1, 2] == 8.0


def test_parse_tsplib_rejects_unsupported_edge_weight_type():
    with pytest.raises(ValueError, match="unsupported EDGE_WEIGHT_TYPE"):
        tsp.parse_tsplib(_tsplib_text("GEO"))
    with pytest.raises(ValueError, match="EXPLICIT"):
        tsp.parse_tsplib(_tsplib_text("EXPLICIT"))


# ------------------------------------------------------------- construction
@pytest.mark.parametrize("method", ["data_parallel", "task_choice",
                                    "task_baseline", "nn_list"])
def test_construction_yields_valid_tours(method):
    inst = tsp.random_instance(40, seed=3)
    prob = aco.make_problem(inst, nn_k=10)
    tau = jnp.ones((40, 40))
    ci = strategies.choice_matrix(tau, prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(
        jax.random.fold_in(KEY, 1), prob.dist, ci, 20, method=method,
        nn=prob.nn, tau=tau, eta=prob.eta)
    tours = np.asarray(res.tours)
    assert tours.shape == (20, 40)
    assert tsp.is_valid_tour(tours)
    lens = np.asarray(res.lengths)
    d = np.asarray(prob.dist)
    for k in range(20):
        np.testing.assert_allclose(
            lens[k], d[tours[k], np.roll(tours[k], -1)].sum(), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(n=hst.integers(4, 60), m=hst.integers(1, 30), seed=hst.integers(0, 999))
def test_construction_property_valid_permutations(n, m, seed):
    inst = tsp.random_instance(n, seed=seed)
    prob = aco.make_problem(inst, nn_k=min(5, n - 1))
    ci = strategies.choice_matrix(jnp.ones((n, n)), prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(
        jax.random.fold_in(KEY, seed), prob.dist, ci, m)
    assert tsp.is_valid_tour(np.asarray(res.tours))


def test_selection_rules_are_distributionally_sane():
    """Exact samplers must hit empirical frequencies ~ weights."""
    w = jnp.array([[0.1, 0.2, 0.3, 0.4]] * 4000)
    for name in ("roulette", "gumbel"):
        keys = jax.random.fold_in(KEY, hash(name) % 1000)
        picks = sampling.select(name, keys, w)
        freq = np.bincount(np.asarray(picks), minlength=4) / picks.shape[0]
        np.testing.assert_allclose(freq, [0.1, 0.2, 0.3, 0.4], atol=0.04)


def test_iroulette_biased_but_ordered():
    """I-Roulette (paper's rule) is not exact but must prefer larger weights."""
    w = jnp.array([[0.1, 0.2, 0.3, 0.4]] * 4000)
    picks = sampling.iroulette(jax.random.fold_in(KEY, 3), w)
    freq = np.bincount(np.asarray(picks), minlength=4) / picks.shape[0]
    assert freq[0] < freq[1] < freq[2] < freq[3]


def test_selectors_never_pick_zero_weight():
    w = jnp.tile(jnp.array([[0.0, 1.0, 0.0, 2.0]]), (1000, 1))
    for name in ("roulette", "iroulette", "gumbel", "greedy"):
        picks = np.asarray(sampling.select(name, jax.random.fold_in(KEY, 5), w))
        assert set(picks) <= {1, 3}, name


# ------------------------------------------------------------- pheromone
@pytest.mark.parametrize("strategy", list(pheromone.STRATEGIES))
def test_deposit_strategies_equivalent(strategy):
    n, m = 36, 18
    inst = tsp.random_instance(n, seed=4)
    prob = aco.make_problem(inst, 8)
    ci = strategies.choice_matrix(jnp.ones((n, n)), prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(jax.random.fold_in(KEY, 9), prob.dist,
                                     ci, m)
    w = 1.0 / res.lengths
    base = pheromone.deposit(n, res.tours, w, "scatter")
    got = pheromone.deposit(n, res.tours, w, strategy)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-4, atol=1e-6)


def test_acs_local_update_deterministic():
    """Regression: duplicate edges (several ants crossing the same edge)
    must give the order-independent sequential-composition result, not a
    last-writer-wins scatter."""
    n, xi, tau0 = 6, 0.2, 0.5
    tau = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n) / 10 + 1
    frm = jnp.array([0, 0, 2, 0, 4], jnp.int32)
    to = jnp.array([1, 1, 3, 1, 5], jnp.int32)
    got = np.asarray(pheromone.local_update_acs(tau, frm, to, xi, tau0))

    exp = np.asarray(tau).copy()
    for f, t in zip(np.asarray(frm), np.asarray(to)):
        for a, b in ((f, t), (t, f)):
            exp[a, b] = (1 - xi) * exp[a, b] + xi * tau0
    np.testing.assert_allclose(got, exp, rtol=1e-6)

    # edge-order permutation invariance (bitwise)
    perm = np.array([4, 2, 0, 3, 1])
    got_p = np.asarray(pheromone.local_update_acs(
        tau, frm[perm], to[perm], xi, tau0))
    np.testing.assert_array_equal(got, got_p)


def test_evaporation():
    tau = jnp.full((10, 10), 2.0)
    np.testing.assert_allclose(pheromone.evaporate(tau, 0.5), 1.0)


def test_full_update_conserves_symmetry():
    n, m = 24, 10
    inst = tsp.random_instance(n, seed=5)
    prob = aco.make_problem(inst, 8)
    ci = strategies.choice_matrix(jnp.ones((n, n)), prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(jax.random.fold_in(KEY, 11), prob.dist,
                                     ci, m)
    tau = jnp.ones((n, n))
    out = np.asarray(pheromone.update(tau, res.tours, 1.0 / res.lengths, 0.5))
    np.testing.assert_allclose(out, out.T, rtol=1e-6)
    assert (out > 0).all()


# ------------------------------------------------------------------ engine
@pytest.mark.parametrize("variant", ["as", "mmas", "acs"])
def test_variants_run_and_improve(variant):
    inst = tsp.circle_instance(32, seed=6)
    cfg = aco.ACOConfig(iterations=15, variant=variant, selection="gumbel")
    st = aco.run(inst, cfg)
    assert np.isfinite(float(st.best_len))
    assert tsp.is_valid_tour(np.asarray(st.best_tour))
    # random tour on a circle is far from optimal; 15 iters must beat 2x opt
    assert float(st.best_len) < 2.0 * inst.known_optimum


def test_mmas_respects_trail_limits():
    inst = tsp.random_instance(20, seed=7)
    cfg = aco.ACOConfig(iterations=10, variant="mmas")
    prob = aco.make_problem(inst, cfg.nn_k)
    st = aco.init_colony(inst, cfg)
    for _ in range(10):
        st, _ = aco.colony_step(prob, st, cfg)
    tau_max = cfg.q / (cfg.rho * float(st.best_len))
    tau = np.asarray(st.tau)
    assert tau.max() <= tau_max * (1 + 1e-5)
    assert tau.min() >= tau_max / (2 * 20) * (1 - 1e-5)


def test_run_scan_matches_python_loop():
    inst = tsp.random_instance(16, seed=8)
    cfg = aco.ACOConfig(iterations=5, selection="gumbel")
    prob = aco.make_problem(inst, cfg.nn_k)
    st0 = aco.init_colony(inst, cfg)
    st_loop = st0
    for _ in range(5):
        st_loop, _ = aco.colony_step(prob, st_loop, cfg)
    st_scan, _ = aco.run_scan(prob, st0, cfg, 5)
    np.testing.assert_allclose(np.asarray(st_loop.tau),
                               np.asarray(st_scan.tau), rtol=1e-6)
    assert float(st_loop.best_len) == float(st_scan.best_len)


def test_pallas_path_equals_reference_quality():
    inst = tsp.circle_instance(24, seed=9)
    base = aco.run(inst, aco.ACOConfig(iterations=10, seed=3))
    fast = aco.run(inst, aco.ACOConfig(iterations=10, seed=3, use_pallas=True))
    # same RNG stream, same semantics -> identical best length
    np.testing.assert_allclose(float(base.best_len), float(fast.best_len),
                               rtol=1e-5)
