"""Supervisor fault-tolerance: injected crashes must not change the
trajectory; restart budget must be enforced."""
import jax
import numpy as np
import pytest

from repro import checkpoint as ck
from repro.core import aco, tsp
from repro.runtime import Supervisor, SupervisorConfig


def _colony_workload(tmp_path, crash_at=None, deadline=None):
    inst = tsp.circle_instance(24, seed=2)
    cfg = aco.ACOConfig(iterations=0, selection="gumbel")
    problem = aco.make_problem(inst, cfg.nn_k)
    crashes = {"left": 1 if crash_at is not None else 0}

    def init():
        return aco.init_colony(inst, cfg)

    def step(state, i):
        if crash_at is not None and i == crash_at and crashes["left"]:
            crashes["left"] -= 1
            raise RuntimeError("injected preemption")
        state, _ = aco.colony_step(problem, state, cfg)
        return state

    mgr = ck.CheckpointManager(str(tmp_path), keep=2, async_write=False)
    sup = Supervisor(SupervisorConfig(total_steps=12, ckpt_every=4,
                                      step_deadline_s=deadline),
                     mgr, init, step)
    return sup


def test_crash_recovery_reproduces_trajectory(tmp_path):
    clean = _colony_workload(tmp_path / "clean").run()
    crashed_sup = _colony_workload(tmp_path / "crash", crash_at=6)
    crashed = crashed_sup.run()
    assert crashed_sup.restarts == 1
    np.testing.assert_allclose(np.asarray(crashed.tau),
                               np.asarray(clean.tau), rtol=1e-6)
    assert float(crashed.best_len) == float(clean.best_len)
    assert int(crashed.iteration) == int(clean.iteration) == 12


def test_restart_budget_enforced(tmp_path):
    inst = tsp.circle_instance(16, seed=3)
    cfg = aco.ACOConfig()
    mgr = ck.CheckpointManager(str(tmp_path), async_write=False)

    def bad_step(state, i):
        raise RuntimeError("permanently broken node")

    sup = Supervisor(SupervisorConfig(total_steps=5, max_restarts=2),
                     mgr, lambda: aco.init_colony(inst, cfg), bad_step)
    with pytest.raises(RuntimeError, match="exceeded 2 restarts"):
        sup.run()
    assert sup.restarts == 3


def test_deadline_triggers_restart_path(tmp_path):
    import time
    slow = {"done": False}
    inst = tsp.circle_instance(16, seed=4)
    cfg = aco.ACOConfig()
    problem = aco.make_problem(inst, cfg.nn_k)

    def step(state, i):
        if i == 2 and not slow["done"]:
            slow["done"] = True
            time.sleep(0.05)          # straggler once
        st, _ = aco.colony_step(problem, state, cfg)
        return st

    # warm the jit cache so compile time doesn't trip the deadline
    aco.colony_step(problem, aco.init_colony(inst, cfg), cfg)

    mgr = ck.CheckpointManager(str(tmp_path), async_write=False)
    sup = Supervisor(SupervisorConfig(total_steps=6, ckpt_every=2,
                                      step_deadline_s=0.04),
                     mgr, lambda: aco.init_colony(inst, cfg), step)
    out = sup.run()
    assert sup.restarts == 1
    assert int(out.iteration) == 6
