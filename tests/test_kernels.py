"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles.

Shape/dtype sweeps + hypothesis property tests per the task requirements.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hyputil import given, settings, hst

from repro.kernels import ops, ref
from repro.kernels import choice_info as ci_k
from repro.kernels import tour_select as ts_k
from repro.kernels import pheromone_update as pu_k

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------- choice_info
@pytest.mark.parametrize("n", [8, 48, 100, 280, 513])
@pytest.mark.parametrize("alpha,beta", [(1.0, 2.0), (2.0, 3.0), (0.5, 2.5)])
def test_choice_info_matches_ref(n, alpha, beta):
    k1, k2 = jax.random.split(jax.random.fold_in(KEY, n))
    tau = jax.random.uniform(k1, (n, n)) + 0.1
    eta = jax.random.uniform(k2, (n, n)) + 0.1
    got = ci_k.choice_info(tau, eta, alpha, beta, interpret=True)
    exp = ref.choice_info(tau, eta, alpha, beta)
    np.testing.assert_allclose(got, exp, rtol=1e-6)


@pytest.mark.parametrize("bm,bn", [(8, 128), (256, 512), (16, 256)])
def test_choice_info_block_shape_invariance(bm, bn):
    tau = jax.random.uniform(jax.random.fold_in(KEY, 1), (200, 200)) + 0.1
    eta = jax.random.uniform(jax.random.fold_in(KEY, 2), (200, 200)) + 0.1
    got = ci_k.choice_info(tau, eta, 1.0, 2.0, block_m=bm, block_n=bn,
                           interpret=True)
    exp = ref.choice_info(tau, eta, 1.0, 2.0)
    np.testing.assert_allclose(got, exp, rtol=1e-6)


# ---------------------------------------------------------------- tour_select
def _select_case(m, n, mode, seed=0, block_n=512):
    k = jax.random.fold_in(KEY, seed * 131 + m * 7 + n)
    rows = jax.random.uniform(k, (m, n)) + 0.01
    vis = jax.random.uniform(jax.random.fold_in(k, 1), (m, n)) < 0.5
    vis = vis.at[:, -1].set(False)  # keep >=1 selectable city per ant
    rand = jax.random.uniform(jax.random.fold_in(k, 2), (m, n),
                              minval=1e-6, maxval=1.0)
    got = ts_k.tour_select(rows, vis, rand, mode, block_n=block_n,
                           interpret=True)
    exp = ref.tour_select(rows, vis.astype(jnp.int8), rand, mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@pytest.mark.parametrize("mode", ["iroulette", "gumbel", "greedy"])
@pytest.mark.parametrize("m,n", [(1, 7), (5, 48), (16, 513), (48, 48),
                                 (100, 1002), (3, 2392)])
def test_tour_select_matches_ref(mode, m, n):
    _select_case(m, n, mode)


@pytest.mark.parametrize("block_n", [128, 256, 512, 1024])
def test_tour_select_tile_invariance(block_n):
    """The paper's tiling must not change the selected city."""
    _select_case(32, 1002, "iroulette", seed=9, block_n=block_n)


def test_tour_select_never_picks_visited():
    m, n = 64, 300
    k = jax.random.fold_in(KEY, 77)
    rows = jax.random.uniform(k, (m, n)) + 0.01
    vis = jax.random.uniform(jax.random.fold_in(k, 1), (m, n)) < 0.8
    vis = vis.at[:, 0].set(False)
    rand = jax.random.uniform(jax.random.fold_in(k, 2), (m, n),
                              minval=1e-6, maxval=1.0)
    for mode in ("iroulette", "gumbel", "greedy"):
        got = np.asarray(ts_k.tour_select(rows, vis, rand, mode,
                                          interpret=True))
        picked_visited = np.asarray(vis)[np.arange(m), got]
        assert not picked_visited.any(), mode


@settings(max_examples=25, deadline=None)
@given(m=hst.integers(1, 40), n=hst.integers(2, 200),
       mode=hst.sampled_from(["iroulette", "gumbel", "greedy"]),
       seed=hst.integers(0, 2**16))
def test_tour_select_property(m, n, mode, seed):
    _select_case(m, n, mode, seed=seed)


# ----------------------------------------------------------- pheromone_update
def _pheromone_case(n, m, rho, seed=0, blocks=(128, 128, 512)):
    k = jax.random.fold_in(KEY, seed * 997 + n * 13 + m)
    tau = jax.random.uniform(k, (n, n)) + 0.5
    tours = jnp.stack([
        jax.random.permutation(jax.random.fold_in(k, 100 + i), n)
        for i in range(m)
    ]).astype(jnp.int32)
    w = jax.random.uniform(jax.random.fold_in(k, 999), (m,)) + 0.1
    frm = tours.ravel()
    to = jnp.roll(tours, -1, axis=-1).ravel()
    wrep = jnp.repeat(w, n)
    f2 = jnp.concatenate([frm, to])
    t2 = jnp.concatenate([to, frm])
    w2 = jnp.concatenate([wrep, wrep])
    got = pu_k.pheromone_update(tau, f2, t2, w2, rho,
                                block_i=blocks[0], block_j=blocks[1],
                                block_e=blocks[2], interpret=True)
    exp = ref.pheromone_update(tau, f2, t2, w2, rho)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
    return got


@pytest.mark.parametrize("n,m", [(8, 4), (48, 48), (100, 30), (280, 64),
                                 (130, 60)])
@pytest.mark.parametrize("rho", [0.1, 0.5])
def test_pheromone_update_matches_ref(n, m, rho):
    _pheromone_case(n, m, rho)


@pytest.mark.parametrize("blocks", [(32, 32, 64), (64, 128, 256),
                                    (128, 128, 512), (128, 64, 1024)])
def test_pheromone_update_block_invariance(blocks):
    _pheromone_case(150, 40, 0.5, seed=3, blocks=blocks)


def test_pheromone_update_symmetry():
    """Symmetric edge duplication must give a symmetric deposit on
    a symmetric starting matrix."""
    n, m = 96, 24
    k = jax.random.fold_in(KEY, 5)
    base = jax.random.uniform(k, (n, n))
    tau = base + base.T
    tours = jnp.stack([
        jax.random.permutation(jax.random.fold_in(k, i), n) for i in range(m)
    ]).astype(jnp.int32)
    w = jnp.ones((m,), jnp.float32)
    out = np.asarray(ops.pheromone_update(tau, tours, w, 0.5))
    np.testing.assert_allclose(out, out.T, rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(n=hst.integers(4, 120), m=hst.integers(1, 30),
       rho=hst.floats(0.05, 0.95), seed=hst.integers(0, 2**16))
def test_pheromone_update_property(n, m, rho, seed):
    _pheromone_case(n, m, float(np.float32(rho)), seed=seed)


def test_pheromone_update_edge_padding_is_inert():
    """-1 endpoints (padding) must not contribute."""
    n = 64
    tau = jnp.ones((n, n))
    frm = jnp.array([-1] * 100, jnp.int32)
    to = jnp.array([-1] * 100, jnp.int32)
    w = jnp.ones((100,), jnp.float32)
    out = pu_k.pheromone_update(tau, frm, to, w, 0.25, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 0.75 * np.ones((n, n)),
                               rtol=1e-6)
