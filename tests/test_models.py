"""Model substrate correctness: MoE dispatch vs dense oracle, SSD chunked
scan vs naive recurrence, decode-cache consistency vs full forward, SWA ring
buffer, MLA cache, optimizer, data pipeline determinism."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers, model, moe, ssm
from repro.models.config import LayerSpec, ModelConfig
from repro.optim import adamw, compression

KEY = jax.random.PRNGKey(0)


def f32(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


# ------------------------------------------------------------------- MoE
def test_moe_sparse_matches_dense_oracle():
    cfg = f32(ModelConfig(
        name="t", n_layers=1, d_model=32, n_heads=4, n_kv=4, d_ff=64,
        vocab=64, n_experts=4, top_k=2, capacity_factor=4.0,  # no drops
        period=(LayerSpec(moe=True),)))
    p = moe.init_moe(jax.random.fold_in(KEY, 1), cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 8, 32))
    got, aux = moe.moe_layer(p, x, cfg)
    exp = moe.moe_layer_dense_eval(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-4, atol=2e-5)
    assert float(aux) > 0


def test_moe_shared_expert_always_active():
    cfg = f32(ModelConfig(
        name="t", n_layers=1, d_model=16, n_heads=2, n_kv=2, d_ff=32,
        vocab=64, n_experts=4, top_k=1, n_shared_experts=1,
        capacity_factor=4.0, period=(LayerSpec(moe=True),)))
    p = moe.init_moe(jax.random.fold_in(KEY, 3), cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (1, 4, 16))
    got, _ = moe.moe_layer(p, x, cfg)
    exp = moe.moe_layer_dense_eval(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-4, atol=2e-5)


def test_moe_capacity_drops_tokens_gracefully():
    cfg = f32(ModelConfig(
        name="t", n_layers=1, d_model=16, n_heads=2, n_kv=2, d_ff=32,
        vocab=64, n_experts=2, top_k=1, capacity_factor=0.25,
        period=(LayerSpec(moe=True),)))
    p = moe.init_moe(jax.random.fold_in(KEY, 5), cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (2, 16, 16))
    got, _ = moe.moe_layer(p, x, cfg)   # must not error; dropped -> zeros
    assert np.isfinite(np.asarray(got)).all()


# ------------------------------------------------------------------- SSD
def _naive_ssm(x, dt, A, B, C, D):
    """Token-by-token recurrence oracle for SSD."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = np.repeat(np.asarray(B), rep, axis=2)
    Ch = np.repeat(np.asarray(C), rep, axis=2)
    xn, dtn, An, Dn = map(np.asarray, (x, dt, A, D))
    state = np.zeros((b, h, p, n))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        dA = np.exp(dtn[:, t] * An[None, :])                  # (b,h)
        inp = np.einsum("bhn,bhp->bhpn", Bh[:, t], xn[:, t] * dtn[:, t][..., None])
        state = state * dA[..., None, None] + inp
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch[:, t]) \
            + xn[:, t] * Dn[None, :, None]
    return ys, state


@pytest.mark.parametrize("s,chunk", [(16, 4), (32, 8), (24, 24)])
def test_ssd_chunked_matches_naive(s, chunk):
    b, h, p, g, n = 2, 4, 8, 2, 16
    k = jax.random.fold_in(KEY, s * 10 + chunk)
    x = jax.random.normal(k, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(k, 1), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(k, 2), (h,)) * 0.5)
    B = jax.random.normal(jax.random.fold_in(k, 3), (b, s, g, n))
    C = jax.random.normal(jax.random.fold_in(k, 4), (b, s, g, n))
    D = jnp.ones((h,))
    y, final = ssm._ssd_chunked(x, dt, A, B, C, D, chunk)
    y_ref, state_ref = _naive_ssm(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), state_ref, rtol=1e-4,
                               atol=1e-4)


def test_mamba_decode_matches_prefill():
    """Step-by-step decode must reproduce the full-sequence forward."""
    cfg = f32(ModelConfig(
        name="t", n_layers=1, d_model=32, n_heads=1, n_kv=1, d_ff=0,
        vocab=64, period=(LayerSpec(kind="mamba"),), ssm_state=8,
        ssm_head_dim=8, ssm_chunk=4))
    p = ssm.init_mamba(jax.random.fold_in(KEY, 7), cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 8), (2, 12, 32))
    y_full, _ = ssm.mamba_forward(p, x, cfg, cache=None)
    cache = ssm.init_mamba_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(12):
        y, cache = ssm.mamba_forward(p, x[:, t: t + 1], cfg, cache)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)


# -------------------------------------------------------- attention caches
def _decode_matches_forward(cfg: ModelConfig, seq: int = 12):
    cfg = f32(cfg)
    params = model.init_params(jax.random.fold_in(KEY, 11), cfg)
    toks = jax.random.randint(jax.random.fold_in(KEY, 12), (2, seq), 0,
                              cfg.vocab)
    enc = None
    if cfg.enc_dec:
        enc = jax.random.normal(jax.random.fold_in(KEY, 13),
                                (2, 6, cfg.d_model))
    full_logits, _ = model.forward(params, toks, cfg, enc_frames=enc)
    step_logits, caches, _ = model.prefill(params, toks, cfg, seq + 1,
                                           enc_frames=enc)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_gqa_decode_matches_forward():
    _decode_matches_forward(ModelConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv=2, d_ff=64,
        vocab=64, period=(LayerSpec(),)))


def test_swa_decode_matches_forward():
    _decode_matches_forward(ModelConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv=2, d_ff=64,
        vocab=64, window=4, period=(LayerSpec(),)), seq=16)


def test_mla_decode_matches_forward():
    _decode_matches_forward(ModelConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv=4, d_ff=64,
        vocab=64, attn_kind="mla", q_lora_rank=16, kv_lora_rank=8,
        qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8, period=(LayerSpec(),)))


def test_encdec_decode_matches_forward():
    _decode_matches_forward(ModelConfig(
        name="t", n_layers=2, d_model=32, n_heads=4, n_kv=4, d_ff=64,
        vocab=64, enc_dec=True, n_enc_layers=2,
        period=(LayerSpec(cross_attn=True),), mlp_kind="mlp", act="gelu",
        norm="layernorm", rope="none", pos_embed="sinusoidal"))


def test_mrope_matches_rope_on_text_positions():
    """With t==h==w position streams, M-RoPE must reduce to plain RoPE."""
    x = jax.random.normal(jax.random.fold_in(KEY, 20), (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
    plain = layers.apply_rope(x, pos, 1e4)
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    mr = layers.apply_mrope(x, pos3, 1e4, (6, 5, 5))
    np.testing.assert_allclose(np.asarray(mr), np.asarray(plain), rtol=1e-5,
                               atol=1e-5)


def test_swa_window_masks_distant_tokens():
    """A distant token outside the window must not affect attention output."""
    cfg = f32(ModelConfig(name="t", n_layers=1, d_model=16, n_heads=2,
                          n_kv=2, d_ff=32, vocab=32, window=3,
                          period=(LayerSpec(),)))
    p = layers.init_attention(jax.random.fold_in(KEY, 21), cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 22), (1, 8, 16))
    pos = layers.positions_like(x[..., 0])
    out1, _ = layers.attention(p, x, cfg, pos)
    x2 = x.at[:, 0].set(x[:, 0] + 100.0)      # outside window of t>=4
    out2, _ = layers.attention(p, x2, cfg, pos)
    np.testing.assert_allclose(np.asarray(out1[:, 5:]),
                               np.asarray(out2[:, 5:]), rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- optimizer/data
def test_adamw_reduces_loss_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                            weight_decay=0.0)
    st = adamw.adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, st, _ = adamw.adamw_update(cfg, g, st, params)
    assert float(loss(params)) < 0.05


def test_gradient_compression_error_feedback():
    g = {"w": jax.random.normal(KEY, (64, 64)) * 0.01}
    state = None
    acc_true = np.zeros((64, 64))
    acc_deq = np.zeros((64, 64))
    for i in range(20):
        gi = {"w": g["w"] * (1 + 0.1 * i)}
        q, s, state = compression.compress_grads(gi, state)
        deq = compression.decompress_grads(q, s)
        acc_true += np.asarray(gi["w"])
        acc_deq += np.asarray(deq["w"])
    # error feedback keeps the *accumulated* quantised sum close to true
    rel = np.abs(acc_deq - acc_true).max() / np.abs(acc_true).max()
    assert rel < 0.05, rel


def test_data_pipeline_deterministic_and_resumable():
    from repro.data import DataConfig, SyntheticLMData
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=4, seed=3)
    a = SyntheticLMData(cfg)
    b1 = next(a)
    b2 = next(a)
    resumed = SyntheticLMData.restore(cfg, {"step": 1, "seed": 3})
    r2 = next(resumed)
    np.testing.assert_array_equal(b2[0], r2[0])
    fresh = SyntheticLMData(cfg)
    f1 = next(fresh)
    np.testing.assert_array_equal(b1[0], f1[0])
    # learnable structure: repeated ngrams present
    toks = b1[0]
    assert (toks[:, 8:16] == toks[:, 0:8]).mean() > 0.9
