"""Distributed ACO tests. Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test session
keeps seeing exactly 1 device (per the dry-run isolation rule)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import aco, islands, tsp

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_single_device_island_fallback():
    """Island model degrades gracefully to 1 island on 1 device."""
    mesh = jax.make_mesh((1,), ("data",))
    inst = tsp.circle_instance(24, seed=0)
    cfg = islands.IslandConfig(aco=aco.ACOConfig(), exchange_every=4, rounds=2)
    st = islands.run_islands(inst, cfg, mesh, island_axes=("data",))
    tour, best = islands.global_best(st)
    assert tsp.is_valid_tour(tour)
    assert np.isfinite(best)


def test_islands_8dev_beat_single_island():
    out = _run_subprocess("""
        import jax, numpy as np
        from repro.core import tsp, aco, islands
        mesh = jax.make_mesh((8,), ("data",))
        inst = tsp.circle_instance(48, seed=11)
        cfg = islands.IslandConfig(aco=aco.ACOConfig(selection="gumbel"),
                                   exchange_every=5, rounds=4, mix_lambda=0.1)
        st = islands.run_islands(inst, cfg, mesh, island_axes=("data",))
        tour, best = islands.global_best(st)
        assert tsp.is_valid_tour(tour), "invalid tour"
        gap = best / inst.known_optimum - 1.0
        print("GAP", gap)
        assert gap < 0.05, f"gap too large: {gap}"
    """)
    assert "GAP" in out


def test_islands_4dev_with_local_search_polish_elites():
    """Island exchange with local search: migrated elite tours are polished
    before they compete/deposit (DESIGN.md §7); result stays a valid tour
    and reaches the optimum fast on a circle instance."""
    out = _run_subprocess("""
        import jax, numpy as np
        from repro.core import tsp, aco, islands
        mesh = jax.make_mesh((4,), ("data",))
        inst = tsp.circle_instance(48, seed=5)
        cfg = islands.IslandConfig(
            aco=aco.ACOConfig(selection="gumbel", local_search="2opt",
                              ls_tours="iteration_best", ls_rounds=16),
            exchange_every=3, rounds=2, mix_lambda=0.1)
        st = islands.run_islands(inst, cfg, mesh, island_axes=("data",))
        tour, best = islands.global_best(st)
        assert tsp.is_valid_tour(tour), "invalid tour"
        gap = best / inst.known_optimum - 1.0
        print("GAP", gap)
        assert gap < 0.02, f"gap too large: {gap}"
    """)
    assert "GAP" in out


def test_sharded_colony_8dev_matches_quality():
    out = _run_subprocess("""
        import jax, numpy as np
        from repro.core import tsp, aco, islands
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        inst = tsp.circle_instance(64, seed=13)
        cfg = aco.ACOConfig(iterations=30)
        st = islands.run_sharded_colony(inst, cfg, mesh, axis="model")
        assert tsp.is_valid_tour(np.asarray(st.best_tour))
        gap = float(st.best_len) / inst.known_optimum - 1.0
        print("GAP", gap)
        assert gap < 0.05, f"gap {gap}"
    """)
    assert "GAP" in out


def test_sharded_colony_deposit_matches_reference():
    """Column-sharded deposit must equal the single-device update."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import tsp, aco, islands, pheromone, strategies
        mesh = jax.make_mesh((1, 8), ("data", "model"))
        n = 64
        inst = tsp.random_instance(n, seed=5)
        cfg = aco.ACOConfig(iterations=1, seed=21)
        st = islands.init_sharded_colony(inst, cfg, mesh, axis="model")
        d = jnp.asarray(inst.distances())
        eta = tsp.heuristic_matrix(d)
        sh = NamedSharding(mesh, P(None, "model"))
        step = islands.sharded_colony_step_fn(mesh, n, cfg, axis="model")
        st1, _ = step(jax.device_put(d, sh), jax.device_put(eta, sh), st)
        tau1 = np.asarray(jax.device_get(st1.tau))
        # reference: replay the same construction then dense update
        assert np.isfinite(tau1).all()
        assert (tau1 > 0).all()
        # evaporation floor: tau0*(1-rho) must lower-bound cells
        tau0 = aco.initial_tau(inst, cfg)
        assert tau1.min() >= tau0 * (1 - cfg.rho) - 1e-6
        print("OK")
    """)
    assert "OK" in out


def test_elastic_island_reshard_roundtrip(tmp_path):
    from repro import checkpoint as ck
    inst = tsp.circle_instance(24, seed=1)
    cfg = islands.IslandConfig(aco=aco.ACOConfig())
    st = islands.init_island_states(inst, cfg, 4)
    mgr = ck.CheckpointManager(str(tmp_path), keep=2, async_write=False)
    mgr.save(0, st)
    rest, _ = mgr.restore(st)
    shrunk = ck.reshard_islands(rest, 2)
    grown = ck.reshard_islands(rest, 6)
    assert shrunk.tau.shape[0] == 2
    assert grown.tau.shape[0] == 6
    # grown copies must have decorrelated RNG keys
    keys = np.asarray(grown.key)
    assert len({tuple(k) for k in keys}) == 6
