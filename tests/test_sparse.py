"""Sparse/paged representation (DESIGN.md §12): bitwise parity with the
dense route at k = n-1, kernel-vs-oracle equality, Partial-ACO contract,
overflow adoption, batched engine composition, and route rejections.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aco, tsp
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.ops import UnsupportedKernelRoute
from repro.solver import batch as batch_mod
from repro.solver import engine
from repro.sparse import aco as sa
from repro.sparse import construct, pheromone, store

KEY = jax.random.PRNGKey(7)


def _cfg(**kw):
    base = dict(iterations=5, m=10, seed=3)
    base.update(kw)
    return aco.ACOConfig(**base)


def _instances():
    return [tsp.circle_instance(24), tsp.grid_instance(5)]


# --------------------------------------------------- k = n-1 bitwise parity
@pytest.mark.parametrize("variant", ["as", "mmas", "acs"])
@pytest.mark.parametrize("selection", ["iroulette", "gumbel"])
def test_sparse_equals_dense_at_full_k(variant, selection):
    """With every edge on a candidate page the sparse trajectory IS the
    dense trajectory: tours, lengths, and pheromone, bit for bit."""
    for inst in _instances():
        n = inst.n
        cfg = _cfg(variant=variant, selection=selection)
        dense = aco.run(inst, dataclasses.replace(cfg, sparse=False))
        scfg = dataclasses.replace(cfg, sparse=True, sparse_k=n - 1)
        prob = store.make_sparse_problem(inst, n - 1)
        state = sa.run_sparse(inst, scfg, problem=prob)
        assert np.array_equal(np.asarray(dense.best_tour),
                              np.asarray(state.best_tour))
        assert float(dense.best_len) == float(state.best_len)
        dtau = np.asarray(dense.tau)
        cand = np.asarray(prob.cand)
        rows = np.arange(n)[:, None]
        np.testing.assert_array_equal(dtau[rows, cand],
                                      np.asarray(state.tau))


def test_sparse_candidate_values_bitwise_dense():
    """Stored page distances/eta are bitwise the dense matrix entries."""
    inst = tsp.random_instance(40, seed=9)
    prob = store.make_sparse_problem(inst, 12)
    d = np.asarray(inst.distances(), np.float32)
    eta = np.asarray(tsp.heuristic_matrix(jnp.asarray(d)))
    cand = np.asarray(prob.cand)
    rows = np.arange(inst.n)[:, None]
    np.testing.assert_array_equal(d[rows, cand], np.asarray(prob.cand_dist))
    np.testing.assert_array_equal(eta[rows, cand], np.asarray(prob.cand_eta))


# ------------------------------------------------------- kernel vs oracle
@pytest.mark.parametrize("mode", ["iroulette", "gumbel", "greedy"])
@pytest.mark.parametrize("alpha,beta", [(1.0, 2.0), (0.9, 3.7)])
def test_sparse_select_kernel_matches_ref(mode, alpha, beta):
    m, n, k = 13, 100, 9
    ks = jax.random.split(jax.random.fold_in(KEY, hash(mode) % 1000), 5)
    tau = jax.random.uniform(ks[0], (m, k)) + 0.1
    eta = jax.random.uniform(ks[1], (m, k)) + 0.1
    cand = jax.random.randint(ks[2], (m, k), 0, n)
    cand = jnp.where(jax.random.bernoulli(ks[3], 0.1, (m, k)), -1, cand)
    visited = jax.random.bernoulli(ks[3], 0.4, (m, n))
    rand = jax.random.uniform(ks[4], (m, n), jnp.float32, 1e-6, 1.0)
    pos, have = kops.sparse_select(tau, eta, cand, visited, rand,
                                   alpha, beta, mode)
    rpos, rhave = ref.sparse_select(tau, eta, cand, visited, rand,
                                    alpha, beta, mode)
    np.testing.assert_array_equal(np.asarray(have), np.asarray(rhave))
    live = np.asarray(have).astype(bool)
    np.testing.assert_array_equal(np.asarray(pos)[live],
                                  np.asarray(rpos)[live])


@pytest.mark.parametrize("selection", ["iroulette", "greedy"])
def test_sparse_pallas_route_matches_pure(selection):
    """Pure and pallas sparse routes share draw semantics for iroulette
    (both consume uniforms) and greedy (deterministic), so whole runs are
    bitwise identical.  Gumbel draws differ by design — the kernel route
    transforms uniforms in-kernel — and is covered against the dense
    pallas route below."""
    inst = tsp.random_instance(32, seed=4)
    cfg = _cfg(variant="mmas", sparse=True, sparse_k=8,
               selection=selection)
    pure = sa.run_sparse(inst, cfg)
    pal = sa.run_sparse(inst, dataclasses.replace(cfg, use_pallas=True))
    assert float(pure.best_len) == float(pal.best_len)
    assert np.array_equal(np.asarray(pure.best_tour),
                          np.asarray(pal.best_tour))
    np.testing.assert_array_equal(np.asarray(pure.tau), np.asarray(pal.tau))


@pytest.mark.parametrize("selection", ["gumbel", "iroulette", "greedy"])
def test_sparse_pallas_construction_matches_dense_pallas(selection):
    """use_pallas=True must honour the dense kernel operand contract:
    uniforms in, per-mode transform in-kernel (ops.tour_select_step).  At
    k = n-1 one sparse pallas construction therefore reproduces the dense
    method='pallas' construction bitwise — in particular gumbel, whose
    uniform->gumbel map must happen exactly once (regression: feeding the
    kernel raw Gumbel samples double-transformed them)."""
    from repro.core import strategies
    inst = tsp.random_instance(24, seed=6)
    n = inst.n
    m = 10
    key = jax.random.PRNGKey(12)
    dist = jnp.asarray(inst.distances(), jnp.float32)
    eta = tsp.heuristic_matrix(dist)
    tau = jnp.ones((n, n), jnp.float32)
    ci = strategies.choice_matrix(tau, eta, 1.0, 2.0)
    dense = strategies.construct_tours(key, dist, ci, m, method="pallas",
                                       selection=selection)
    prob = store.make_sparse_problem(inst, n - 1)
    sp = construct.construct_sparse_tours(
        key, prob, jnp.ones((n, n - 1), jnp.float32),
        jnp.full((n, 0), store.OVF_EMPTY, jnp.int32),
        jnp.zeros((n, 0), jnp.float32), m, selection, 1.0, 2.0,
        inst.edge_weight_type, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(dense.tours),
                                  np.asarray(sp.tours))
    np.testing.assert_array_equal(np.asarray(dense.lengths),
                                  np.asarray(sp.lengths))


# ---------------------------------------------------------- Partial-ACO
def test_partial_aco_monotone_and_valid():
    inst = tsp.random_instance(60, seed=11)
    cfg = _cfg(variant="mmas", sparse=True, sparse_k=10,
               construction="partial", partial_window=12, m=16,
               iterations=0)
    prob = store.make_sparse_problem(inst, 10)
    state = sa.init_sparse_colony(inst, cfg)
    assert tsp.is_valid_tour(np.asarray(state.best_tour))
    lens = [float(state.best_len)]
    for _ in range(20):
        state, _ = sa.sparse_colony_step(prob, state, cfg, "RAW")
        lens.append(float(state.best_len))
    assert all(b <= a for a, b in zip(lens, lens[1:]))
    assert tsp.is_valid_tour(np.asarray(state.best_tour))
    # exact length of the final best (delta updates never accumulate error)
    exact = float(store.sparse_tour_length(
        prob, jnp.asarray(state.best_tour)[None, :], "RAW")[0])
    assert float(state.best_len) == exact


# ------------------------------------------------------ overflow adoption
def test_offlist_adoption_and_eviction():
    cand = jnp.asarray([[1, 2], [0, 2], [0, 1], [0, 1]], jnp.int32)  # n=4,k=2
    n = 4
    ovf_city = jnp.full((n, 2), store.OVF_EMPTY, jnp.int32)
    ovf_tau = jnp.zeros((n, 2), jnp.float32)
    # tour 0-1-2-3: edge 0-3 and 3-0... closing edge 3->0 is off-list for
    # neither endpoint? cand[3] = [0, 1] contains 0, cand[0] = [1, 2]
    # misses 3 -> city 0 adopts 3.
    tour = jnp.asarray([0, 1, 2, 3], jnp.int32)
    w = jnp.asarray(0.5, jnp.float32)
    oc, ot = pheromone.adopt_offlist(cand, ovf_city, ovf_tau, tour, w,
                                     jnp.asarray(0.1, jnp.float32), None)
    oc, ot = np.asarray(oc), np.asarray(ot)
    assert 3 in oc[0]                       # 0 adopted off-list partner 3
    slot = list(oc[0]).index(3)
    assert ot[0, slot] == np.float32(0.1 + 0.5)     # tau_def + w
    # matching deposit accumulates instead of re-adopting
    oc2, ot2 = pheromone.adopt_offlist(cand, jnp.asarray(oc),
                                       jnp.asarray(ot), tour, w,
                                       jnp.asarray(0.1, jnp.float32), None)
    oc2, ot2 = np.asarray(oc2), np.asarray(ot2)
    assert list(oc2[0]).count(3) == 1
    assert ot2[0, slot] == np.float32(0.1 + 0.5 + 0.5)


def test_offlist_adoption_evicts_weakest_only_if_stronger():
    cand = jnp.asarray([[1], [0], [0], [0]], jnp.int32)     # n=4, k=1
    ovf_city = jnp.asarray([[2], [-1], [-1], [-1]], jnp.int32)
    strong = jnp.asarray([[9.0], [0.0], [0.0], [0.0]], jnp.float32)
    tour = jnp.asarray([0, 3, 1, 2], jnp.int32)   # edge 0-3 off-list for 0
    w = jnp.asarray(0.5, jnp.float32)
    oc, _ = pheromone.adopt_offlist(cand, ovf_city, strong, tour, w,
                                    jnp.asarray(0.1, jnp.float32), None)
    assert np.asarray(oc)[0, 0] == 2        # newcomer weaker: slot kept
    weak = jnp.asarray([[0.2], [0.0], [0.0], [0.0]], jnp.float32)
    oc, ot = pheromone.adopt_offlist(cand, ovf_city, weak, tour, w,
                                     jnp.asarray(0.1, jnp.float32), None)
    assert np.asarray(oc)[0, 0] == 3        # newcomer stronger: evicted
    assert np.asarray(ot)[0, 0] == np.float32(0.1 + 0.5)


# ------------------------------------------------- batched engine / service
def test_batched_sparse_matches_solo_padded():
    insts = [tsp.circle_instance(20), tsp.random_instance(27, seed=3),
             tsp.grid_instance(5)]
    cfg = _cfg(variant="mmas", sparse=True, sparse_k=8, m=12, iterations=4)
    states, b = engine.solve_instances(insts, cfg, n_pad=32)
    assert isinstance(b, batch_mod.SparseBatch)
    res = engine.collect(states, b)
    for i, inst in enumerate(insts):
        prob = store.make_sparse_problem(inst, 8, 32)._replace(
            n_actual=jnp.asarray(inst.n, jnp.int32))
        s = sa.init_sparse_colony(inst, cfg, cfg.seed + i, 32)
        for _ in range(4):
            s, _ = sa.sparse_colony_step(prob, s, cfg,
                                         inst.edge_weight_type)
        assert float(s.best_len) == res[i]["best_len"]
        assert np.array_equal(np.asarray(s.best_tour)[:inst.n],
                              res[i]["best_tour"])
        assert bool(jnp.all(s.tau == states.tau[i]))
        assert tsp.is_valid_tour(res[i]["best_tour"])


def test_sparse_batch_rejects_mixed_rounding():
    a = tsp.circle_instance(8)
    b = dataclasses.replace(a, edge_weight_type="CEIL_2D") \
        if dataclasses.is_dataclass(a) else None
    if b is None:
        pytest.skip("TSPInstance is not a dataclass")
    with pytest.raises(ValueError, match="edge weight"):
        batch_mod.make_sparse_batch([a, b], 4)


def test_solver_service_sparse_drain():
    from repro.solver import SolverService
    svc = SolverService(_cfg(variant="mmas", sparse=True, sparse_k=8,
                             iterations=3), max_batch=4)
    for inst in _instances():
        svc.submit(inst)
    results = svc.run()
    assert len(results) == 2
    for r in results:
        assert tsp.is_valid_tour(r.best_tour)
        assert r.iterations == 3


# --------------------------------------------------- storage / padding / O()
def test_make_sparse_problem_phantoms_inert():
    inst = tsp.random_instance(10, seed=1)
    prob = store.make_sparse_problem(inst, 4, n_pad=16)
    cand = np.asarray(prob.cand)
    # real rows never list a phantom candidate
    assert (cand[:10] < 10).all()
    # phantom rows are pure self-sentinel with eta 0
    assert (cand[10:] == np.arange(10, 16)[:, None]).all()
    assert (np.asarray(prob.cand_eta)[10:] == 0).all()
    assert prob.n_actual is not None and int(prob.n_actual) == 10


def test_resident_bytes_scale_with_k_not_n_squared():
    inst = tsp.random_instance(200, seed=5)
    cfg = _cfg(variant="mmas", sparse=True, m=8)
    sizes = {}
    for k in (8, 16):
        prob = store.make_sparse_problem(inst, k)
        st = sa.init_sparse_colony(
            inst, dataclasses.replace(cfg, sparse_k=k))
        sizes[k] = store.resident_bytes(prob, st)
        # nothing resident is (n, n)-shaped
        for leaf in jax.tree.leaves((prob, st)):
            assert not (leaf.ndim >= 2 and leaf.shape[-1] == inst.n
                        and leaf.shape[-2] == inst.n)
    assert sizes[16] < store.dense_resident_bytes(inst.n) / 4
    # doubling k roughly doubles the (n, k) pages (fixed overhead aside)
    assert sizes[16] - sizes[8] == pytest.approx(sizes[8], rel=0.8)


def test_edge_sum_matches_pairwise_fold():
    for ln in (1, 2, 5, 8, 13):
        x = np.asarray(jax.random.uniform(jax.random.fold_in(KEY, ln),
                                          (3, ln)), np.float64)
        got = np.asarray(tsp.edge_sum(jnp.asarray(x, jnp.float32)))
        np.testing.assert_allclose(got, x.sum(-1).astype(np.float32),
                                   rtol=1e-5)


# ------------------------------------------------------- route rejections
@pytest.mark.parametrize("kw,match", [
    (dict(selection="roulette"), "roulette"),
    (dict(local_search="2opt"), "local_search"),
    (dict(construction="nn_list"), "construction"),
])
def test_sparse_route_rejections(kw, match):
    cfg = _cfg(variant="mmas", sparse=True, **kw)
    with pytest.raises(UnsupportedKernelRoute, match=match):
        sa.check_sparse_route(cfg)


def test_sparse_rejects_partial_on_masked_and_streaming_mesh():
    cfg = _cfg(sparse=True, construction="partial")
    with pytest.raises(UnsupportedKernelRoute, match="padded"):
        sa.check_sparse_route(cfg, masked=True)
    with pytest.raises(UnsupportedKernelRoute, match="streaming"):
        kops.check_kernel_route(sparse=True, streaming=True)
    with pytest.raises(UnsupportedKernelRoute, match="mesh"):
        kops.check_kernel_route(sparse=True, mesh=True)
    with pytest.raises(UnsupportedKernelRoute, match="Hyper"):
        kops.check_kernel_route(sparse=True, hyper=True)


def test_streaming_service_rejects_sparse():
    from repro.solver import StreamingSolverService
    with pytest.raises(UnsupportedKernelRoute, match="streaming"):
        StreamingSolverService(_cfg(sparse=True))
