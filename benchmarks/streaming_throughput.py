"""Streaming (continuous batching) vs drain-the-queue under Poisson arrivals.

The same heterogeneous-budget arrival trace is replayed through both
schedulers:

- ``drain``     SolverService: whenever the queue is non-empty, drain it in
                <= max_batch jobs; arrivals during a job wait for the full
                drain, and a straggler budget holds its whole batch;
- ``streaming`` StreamingSolverService: resident slots, chunked stepping,
                finished slots harvested and refilled mid-run (DESIGN.md §9).

Budgets mix short and long requests (the straggler pattern LM-serving
engines built continuous batching for); sizes all land in one bucket so
the comparison isolates scheduling, not padding.  The arrival rate is
calibrated from a measured all-at-once drain of the same workload, so the
trace applies continuous pressure on any host speed.  Both modes are
compile-warmed (every (B, max_iters) drain shape + the streaming chunk
program) before timing.

Emits ``BENCH_streaming.json`` at the repo root.

    PYTHONPATH=src python benchmarks/streaming_throughput.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import aco, quant, tsp
from repro.solver import SolverService, StreamingSolverService, engine, \
    streaming

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_streaming.json")

# Budgets cycle short,short,short,STRAGGLER — the pattern continuous
# batching exists for: in drain mode every max_batch slice holding a
# straggler pays max(budgets) iterations across all its lanes, while
# streaming harvests the shorts at chunk boundaries and refills their
# slots from the queue.  ``pressure`` compresses all arrivals into that
# fraction of the measured busy time, so the queue stays deep enough that
# drain must take mixed slices (and freed streaming slots always have
# work).  The final requests are all shorts so the finite-trace tail
# drains fast instead of measuring a near-empty pool — an artifact a real
# unbounded stream doesn't have.  ``chunk`` equals the short budget:
# shorts harvest after exactly one tick, stragglers after ten.
CASE = dict(bucket=32, slots=4, requests=32, min_n=17, max_n=32,
            iters=(4, 4, 4, 40) * 6 + (4,) * 8, chunk=4, seed=0,
            pressure=0.2)
SMOKE_CASE = dict(bucket=32, slots=4, requests=20, min_n=17, max_n=32,
                  iters=(3, 3, 3, 30) * 4 + (3,) * 4, chunk=3, seed=0,
                  pressure=0.2)


def _make_trace(case, rate: float) -> list[streaming.TraceItem]:
    return streaming.make_poisson_trace(
        case["requests"], rate, case["min_n"], case["max_n"],
        seed=case["seed"], iterations=case["iters"])


def _warm(case, cfg) -> float:
    """Compile-warm every program either mode can hit, and return the
    busy-drain wall time of the whole workload (rate calibration)."""
    probe = _make_trace(case, rate=1e9)
    insts = [t.instance for t in probe]
    budgets = [t.iterations for t in probe]
    bucket = case["bucket"]
    # drain shapes: every batch size 1..slots x every distinct max-budget
    for b in range(1, case["slots"] + 1):
        for it in sorted(set(case["iters"])):
            engine.solve_instances(insts[:b], cfg, iterations=[it] * b,
                                   seeds=list(range(b)), n_pad=bucket)
    # streaming shape: (slots, chunk) resident program + refill surgery
    warm_svc = StreamingSolverService(cfg, max_batch=case["slots"],
                                      min_bucket=bucket, chunk=case["chunk"])
    for k, inst in enumerate(insts[:case["slots"] + 1]):
        warm_svc.submit(inst, iterations=case["chunk"], seed=k)
    warm_svc.run_until_drained()
    # calibration: timed all-at-once drain (everything already compiled)
    svc = SolverService(cfg, max_batch=case["slots"], min_bucket=bucket)
    for inst, it in zip(insts, budgets):
        svc.submit(inst, iterations=it)
    t0 = time.perf_counter()
    svc.run()
    return time.perf_counter() - t0


def _replay_drain(svc: SolverService, trace) -> list:
    """Drain-mode counterpart of streaming.replay_trace: same arrival
    polling, but the scheduler blocks in run() (full-queue drains) instead
    of stepping chunks — that blocking is the baseline being measured."""
    start = time.perf_counter()
    i, results = 0, []
    while i < len(trace) or svc.pending:
        now = time.perf_counter() - start
        while i < len(trace) and trace[i].at <= now:
            it = trace[i]
            svc.submit(it.instance, iterations=it.iterations, seed=it.seed)
            i += 1
        if svc.pending:
            results.extend(svc.run())
        elif i < len(trace):
            time.sleep(max(0.0, trace[i].at - (time.perf_counter() - start)))
    return results


def _row(mode: str, results, wall: float, extra=None) -> dict:
    lat = [r.latency_s for r in results]
    row = {
        "mode": mode, "requests": len(results),
        "wall_s": round(wall, 4),
        "ips": round(len(results) / wall, 3),
        "lat_mean_s": round(float(np.mean(lat)), 4),
        "lat_p50_s": round(float(np.percentile(lat, 50)), 4),
        "lat_p95_s": round(float(np.percentile(lat, 95)), 4),
    }
    row.update(extra or {})
    return row


def residency_rows(case) -> list[dict]:
    """Resident-state footprint of one streaming slot per ``tau_dtype``
    (DESIGN.md §15).  Deterministic byte counts, no timing: the quantised
    store's capacity claim is how many resident colonies fit per GB when
    the (n, n) tau payload drops to bf16/int8 (+ per-row scales)."""
    bucket = case["bucket"]
    inst = tsp.random_instance(bucket, seed=0)
    out, fp32_tau = [], None
    for tau_dtype in ("fp32", "bf16", "int8"):
        cfg = aco.ACOConfig(iterations=1, selection="gumbel",
                            tau_dtype=tau_dtype)
        st = engine.init_states([inst], cfg, [0], bucket)
        slot_bytes = quant.tau_nbytes(st)          # sums every state leaf
        tau_bytes = quant.tau_nbytes(st.tau)
        fp32_tau = fp32_tau if fp32_tau is not None else tau_bytes
        out.append({
            "tau_dtype": tau_dtype, "bucket": bucket,
            "state_bytes_per_slot": slot_bytes,
            "tau_bytes_per_slot": tau_bytes,
            "tau_fp32_over_quant": round(fp32_tau / tau_bytes, 2),
            "slots_per_gb": int(1e9 // slot_bytes),
        })
    return out


REPS = 3   # best-of-N replays per mode (min wall) to damp scheduler noise


def run_case(case) -> list[dict]:
    cfg = aco.ACOConfig(iterations=max(case["iters"]), selection="gumbel")
    busy_s = _warm(case, cfg)
    # arrivals spread over ``pressure`` x the busy time: continuous queue
    # pressure (so freed slots always have work to take) while the tail of
    # the trace still arrives mid-flight.
    rate = case["requests"] / max(case["pressure"] * busy_s, 1e-3)
    trace = _make_trace(case, rate)

    best_d = best_s = None
    for _ in range(REPS):
        svc_d = SolverService(cfg, max_batch=case["slots"],
                              min_bucket=case["bucket"])
        t0 = time.perf_counter()
        res_d = _replay_drain(svc_d, trace)
        wall_d = time.perf_counter() - t0
        assert len(res_d) == case["requests"]
        if best_d is None or wall_d < best_d[1]:
            best_d = (res_d, wall_d)

        svc_s = StreamingSolverService(cfg, max_batch=case["slots"],
                                       min_bucket=case["bucket"],
                                       chunk=case["chunk"])
        t0 = time.perf_counter()
        res_s = streaming.replay_trace(svc_s, trace)
        wall_s = time.perf_counter() - t0
        assert len(res_s) == case["requests"]
        if best_s is None or wall_s < best_s[1]:
            best_s = (res_s, wall_s,
                      round(svc_s.stats["occupancy_mean"], 4))

    return [_row("drain", best_d[0], best_d[1]),
            _row("streaming", best_s[0], best_s[1],
                 {"occupancy_mean": best_s[2]})]


def main(case=CASE, out_path: str | None = None):
    out_path = out_path or DEFAULT_OUT
    print("streaming vs drain under Poisson arrivals "
          f"(bucket={case['bucket']}, slots={case['slots']}, "
          f"budgets={case['iters']})")
    rows = run_case(case)
    hdr = list(rows[1])
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in hdr))
    drain, stream = rows
    residency = residency_rows(case)
    res_by_dt = {r["tau_dtype"]: r for r in residency}
    summary = {
        "ips_ratio": round(stream["ips"] / drain["ips"], 3),
        "lat_mean_ratio": round(stream["lat_mean_s"] / drain["lat_mean_s"],
                                3),
        "tau_ratio_bf16": res_by_dt["bf16"]["tau_fp32_over_quant"],
        "tau_ratio_int8": res_by_dt["int8"]["tau_fp32_over_quant"],
    }
    print(f"streaming/drain: {summary['ips_ratio']}x ips, "
          f"{summary['lat_mean_ratio']}x mean latency")
    for r in residency:
        print(f"residency[{r['tau_dtype']}]: "
              f"{r['state_bytes_per_slot']} B/slot "
              f"(tau {r['tau_bytes_per_slot']} B, "
              f"{r['tau_fp32_over_quant']}x smaller), "
              f"{r['slots_per_gb']} slots/GB")
    payload = {
        "benchmark": "streaming_throughput",
        "schema": 1,
        "unix_time": int(time.time()),
        "case": {k: v for k, v in case.items()},
        "rows": rows,
        "residency": residency,
        "summary": summary,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {os.path.abspath(out_path)}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast case")
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default: {DEFAULT_OUT})")
    args = ap.parse_args()
    main(SMOKE_CASE if args.smoke else CASE, args.out)
