"""Paper Fig. 4: full-iteration speed-up of the accelerated AS over the
sequential CPU code (here: pure-NumPy SequentialAS standing in for Stützle's
ANSI-C, vs the jitted JAX colony step).

Fig 4(a): NN-list construction (NN=30). Fig 4(b): fully probabilistic
data-parallel construction. Absolute speed-ups are CPU-vs-CPU (one core) and
NOT comparable to the paper's GPU numbers; the claim under test is the
*shape*: speed-up grows with n, and data-parallel wins more at small n
than task-style at small n (C1).
"""
from __future__ import annotations

import jax

from repro.core import aco, sequential, tsp

from .timing import time_fn, time_host_fn

SIZES = (48, 100, 280)
FULL_SIZES = (48, 100, 280, 442)


def rows(sizes=SIZES):
    out = []
    for n in sizes:
        inst = tsp.random_instance(n, seed=n)
        d = inst.distances()
        seq = sequential.SequentialAS(d, m=n, seed=0)
        seq_ms = time_host_fn(seq.iterate, iters=1)
        seq_nn = sequential.SequentialAS(d, m=n, seed=0, nn_k=min(30, n - 1))
        seq_nn_ms = time_host_fn(seq_nn.iterate, iters=1)

        prob = aco.make_problem(inst, nn_k=min(30, n - 1))

        def one_iter(cfg):
            st = aco.init_colony(inst, cfg)
            step = lambda s: aco.colony_step(prob, s, cfg)[0]
            return time_fn(step, st, warmup=1, iters=3)

        dp_ms = one_iter(aco.ACOConfig(construction="data_parallel"))
        nn_ms = one_iter(aco.ACOConfig(construction="nn_list"))
        out.append({
            "n": n,
            "seq_full_ms": seq_ms, "jax_data_parallel_ms": dp_ms,
            "fig4b_speedup": seq_ms / dp_ms,
            "seq_nn_ms": seq_nn_ms, "jax_nnlist_ms": nn_ms,
            "fig4a_speedup": seq_nn_ms / nn_ms,
        })
    return out


def main(sizes=SIZES):
    print("fig4_overall (ms per full AS iteration; speedup vs sequential)")
    hdr = None
    for r in rows(sizes):
        if hdr is None:
            hdr = list(r.keys())
            print(",".join(hdr))
        print(",".join(f"{r[k]:.2f}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))


if __name__ == "__main__":
    main()
