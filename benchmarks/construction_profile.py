"""Construction hot-path profile: per-stage timing across kernel routes.

Mirrors the paper's stage breakdown (tour construction vs pheromone
update — the two kernels its Tables II/III time separately) for the
post-overhaul routes:

- ``dense``          pure-JAX data-parallel construction (gather full
                     choice rows each step) + scatter deposit;
- ``nn_list``        candidate-list construction with the *lazy* dense
                     fallback (count-gated lax.cond — the O(m*n*k) route);
- ``nn_list_eager``  the pre-overhaul unconditional dense fallback, kept
                     registered purely as this regression baseline;
- ``pallas``         the fused choice->select kernel + kernel deposit
                     (interpret mode on CPU: validates wiring, not speed).

The construction stage includes the per-iteration choice-matrix precompute
where the route needs one (the fused kernel route doesn't — that is the
point of fusing).

Every route is compile-warmed, then timed best-of-``REPS`` (container
wall-clock varies up to ~3x between runs; single timings are unreliable).

**Regression assertion** (ISSUE 4 satellite): for n >= 256 the lazy
``nn_list`` route must be >= ``MIN_NN_SPEEDUP`` x the eager baseline in
iterations/sec — if the unconditional dense fallback ever silently
returns, this benchmark fails loudly rather than drifting.

Emits ``BENCH_construction.json`` at the repo root.

    PYTHONPATH=src python benchmarks/construction_profile.py [--full] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import aco, pheromone, strategies, tsp

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_construction.json")

SIZES = (64, 256)
FULL_SIZES = (64, 256, 1024)
REPS = 5
NN_K = 20
MIN_NN_SPEEDUP = 1.3   # lazy nn_list vs eager baseline, n >= 256

ROUTES = ("dense", "nn_list", "nn_list_eager", "pallas")


def _ants(n: int) -> int:
    # paper uses m = n; cap for the CPU-interpret benchmark so n=1024
    # stays in minutes (the stage *split* is what this table reports)
    return min(n, 256)


def _setup(n: int):
    inst = tsp.circle_instance(n, seed=7)
    prob = aco.make_problem(inst, nn_k=min(NN_K, n - 1))
    tau = jnp.full((n, n), aco.initial_tau(inst, aco.ACOConfig()),
                   jnp.float32)
    return prob, tau


def _construct_fn(route: str, prob, tau, n: int):
    """Returns a nullary stage function: one full tour construction."""
    m = _ants(n)
    key = jax.random.PRNGKey(1)
    if route == "pallas":
        def fn():
            # fused route: no choice-matrix precompute at all
            res = strategies.construct_tours(
                key, prob.dist, jnp.zeros((1, 1), jnp.float32), m,
                method="fused", selection="iroulette",
                tau=tau, eta=prob.eta)
            return res.lengths.block_until_ready()
        return fn
    method = {"dense": "data_parallel"}.get(route, route)

    def fn():
        ci = strategies.choice_matrix(tau, prob.eta, 1.0, 2.0)
        res = strategies.construct_tours(
            key, prob.dist, ci, m, method=method, selection="iroulette",
            nn=prob.nn, tau=tau, eta=prob.eta)
        return res.lengths.block_until_ready()
    return fn


def _pheromone_fn(route: str, prob, tau, n: int):
    """Returns a nullary stage function: one full AS deposit."""
    m = _ants(n)
    tours = jnp.stack([jnp.roll(jnp.arange(n, dtype=jnp.int32), i)
                       for i in range(m)])
    w = jnp.full((m,), 0.01, jnp.float32)
    if route == "pallas":
        from repro.kernels import ops as kops

        def fn():
            return kops.pheromone_update(tau, tours, w,
                                         0.5).block_until_ready()
        return fn

    def fn():
        return pheromone.update(tau, tours, w, 0.5,
                                strategy="scatter").block_until_ready()
    return fn


def _best_of(fn, reps: int = REPS) -> float:
    fn()                       # compile warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(sizes=SIZES, out: str = DEFAULT_OUT) -> dict:
    rows = {}
    print(f"{'n':>6} {'route':>14} {'construct_s':>12} {'pheromone_s':>12} "
          f"{'iter/s':>8}")
    for n in sizes:
        prob, tau = _setup(n)
        rows[str(n)] = {}
        for route in ROUTES:
            if route == "pallas" and n > 512:
                # interpret-mode kernels at n=1024 are compile-bound on
                # CPU; the wiring is already validated at smaller n.
                continue
            tc = _best_of(_construct_fn(route, prob, tau, n))
            tp = _best_of(_pheromone_fn(route, prob, tau, n))
            ips = 1.0 / (tc + tp)
            rows[str(n)][route] = {
                "construct_s": tc,
                "pheromone_s": tp,
                "construct_frac": tc / (tc + tp),
                "iter_per_s": ips,
            }
            print(f"{n:>6} {route:>14} {tc:>12.4f} {tp:>12.4f} {ips:>8.2f}")

    speedups = {}
    for n in sizes:
        r = rows[str(n)]
        su = r["nn_list"]["iter_per_s"] / r["nn_list_eager"]["iter_per_s"]
        speedups[str(n)] = su
        print(f"n={n}: lazy nn_list speedup vs eager fallback = {su:.2f}x")

    payload = {
        "sizes": list(sizes),
        "ants": {str(n): _ants(n) for n in sizes},
        "nn_k": NN_K,
        "reps": REPS,
        "stages": rows,
        "nn_lazy_speedup": speedups,
        "min_nn_speedup_required": MIN_NN_SPEEDUP,
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out}")

    # regression gate: the lazy fallback must not silently regress to the
    # eager dense path (ISSUE 4 — candidate lists must buy their win back)
    for n in sizes:
        if n >= 256:
            assert speedups[str(n)] >= MIN_NN_SPEEDUP, (
                f"lazy nn_list construction is only "
                f"{speedups[str(n)]:.2f}x the eager dense-fallback "
                f"baseline at n={n} (required >= {MIN_NN_SPEEDUP}x): the "
                f"count-gated lax.cond fallback has regressed")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    main(FULL_SIZES if args.full else SIZES, args.out)
