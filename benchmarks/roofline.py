"""Roofline table builder: reads experiments/dryrun/*.json (written by
repro.launch.dryrun) and renders the §Roofline table with the three terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs utilisation, and a one-line
what-would-move-it note per cell."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")

NOTES = {
    "compute_s": "compute-bound: raise MXU utilisation (larger per-device "
                 "tiles, fewer pad FLOPs) or shrink redundant recompute",
    "memory_s": "HBM-bound: fuse elementwise chains, cut activation "
                "round-trips (remat policy), widen arithmetic intensity",
    "collective_s": "ICI-bound: reshard to cut gather volume, overlap "
                    "collectives with compute, compress payloads",
}


def load(dirpath: str = DRYRUN_DIR) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def render(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "bottleneck | useful/HLO flops | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | —"
                         f" | — | skipped | — | {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | —"
                         f" | — | FAILED | — | {r.get('error','')[:60]} |")
            continue
        t = r["roofline"]
        ur = t.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {t['bottleneck'][:-2]} "
            f"| {ur:.2f} | {NOTES[t['bottleneck']][:48]} |"
            if ur is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ? | ? | ? | ? | ? | |")
    return "\n".join(lines)


def main() -> None:
    recs = load()
    if not recs:
        print("no dryrun records found — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        return
    ok = [r for r in recs if r["status"] == "ok"]
    table = render(recs)
    print(table)
    print()
    summary = (f"# cells: {len(ok)} ok / "
               f"{sum(r['status'] == 'skipped' for r in recs)} skipped / "
               f"{sum(r['status'] == 'fail' for r in recs)} failed")
    print(summary)
    out = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "roofline.md")
    try:
        with open(out, "w") as f:
            f.write("# Roofline table (final sweep; see EXPERIMENTS.md "
                    "§Roofline for methodology)\n\n" + table + "\n\n"
                    + summary + "\n")
    except OSError:
        pass


if __name__ == "__main__":
    main()
