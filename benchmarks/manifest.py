"""Consolidated benchmark manifest: one discoverable perf-trajectory index.

Every benchmark writes its own ``BENCH_<name>.json`` at the repo root;
this module folds them into one ``BENCH_manifest.json`` — bench name →
file, timestamp, and the *headline* numbers that summarize that bench's
claim (streaming ips ratio, telemetry overhead %, sharded speedup, sparse
residency ratio, ...).  The manifest is what tooling reads first:
``benchmarks/regress.py`` resolves its tolerance checks against the
headline paths, and ``benchmarks/run.py`` refreshes the manifest after
every suite run (DESIGN.md §14).

    PYTHONPATH=src python -m benchmarks.manifest        # (re)write it
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Optional

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
MANIFEST_NAME = "BENCH_manifest.json"
SCHEMA = "repro.bench_manifest/v1"

BENCH_FILES = {
    "coldstart": "BENCH_coldstart.json",
    "construction": "BENCH_construction.json",
    "obs": "BENCH_obs.json",
    "quality": "BENCH_quality.json",
    "sharded": "BENCH_sharded.json",
    "solver": "BENCH_solver.json",
    "sparse": "BENCH_sparse.json",
    "streaming": "BENCH_streaming.json",
}


def _row(rows: list, **match) -> Optional[dict]:
    for r in rows:
        if all(r.get(k) == v for k, v in match.items()):
            return r
    return None


def _headline_coldstart(p: dict) -> dict:
    return {"cold_p99_s": p["rows"]["cold"]["p99_s"],
            "persist_p99_s": p["rows"]["persist"]["p99_s"],
            "warmed_p99_s": p["rows"]["warmed"]["p99_s"],
            "warmed_over_cold": p["warmed_over_cold"],
            "persist_over_cold": p["persist_over_cold"],
            "max_ratio_required": p["max_ratio_required"]}


def _headline_construction(p: dict) -> dict:
    return {"nn_lazy_speedup": p["nn_lazy_speedup"],
            "min_nn_speedup_required": p.get("min_nn_speedup_required")}


def _headline_obs(p: dict) -> dict:
    out = dict(p["summary"])
    off = _row(p["rows"], level="off")
    for r in p["rows"]:
        out[f"{r['level']}_ips"] = r["ips"]
        out[f"{r['level']}_lat_mean_s"] = r["lat_mean_s"]
    if off:
        out["off_occupancy_mean"] = off.get("occupancy_mean")
    return out


def _headline_quality(p: dict) -> dict:
    out = {}
    for r in p.get("rows", []):
        for k in ("iroulette_gap_pct", "gumbel_gap_pct"):
            if k in r:
                out[f"{r['instance']}_{k}"] = r[k]
    for r in p.get("quant_rows", []):
        for k in ("bf16_vs_fp32_pct", "int8_vs_fp32_pct"):
            if k in r:
                out[f"{r['instance']}_{k}"] = r[k]
    return out


def _headline_sharded(p: dict) -> dict:
    d1 = _row(p["rows"], devices=1)
    d8 = _row(p["rows"], devices=8)
    return {"speedup_8v1": p.get("speedup_8v1"),
            "d1_ips": d1 and d1.get("ips"),
            "d8_ips": d8 and d8.get("ips")}


def _headline_solver(p: dict) -> dict:
    out = {}
    for r in p["rows"]:
        out[f"b{r['bucket']}x{r['batch']}_speedup"] = r["speedup"]
        out[f"b{r['bucket']}x{r['batch']}_batch_ips"] = r["batch_ips"]
    return out


def _headline_sparse(p: dict) -> dict:
    out = {}
    for r in p["rows"]:
        key = f"{r['instance']}_k{r['k']}_{r['construction']}"
        dt = r.get("tau_dtype", "fp32")
        if dt != "fp32":                 # quantised residency rows (§15)
            key = f"{key}_{dt}"
            out[f"{key}_tau_bytes"] = r.get("resident_tau_bytes")
            out[f"{key}_tau_fp32_over"] = r.get("tau_fp32_over_quant")
            continue
        out[f"{key}_dense_over_sparse"] = r.get("dense_over_sparse")
        out[f"{key}_resident_bytes"] = r.get("resident_bytes_sparse")
        out[f"{key}_iters_per_s"] = r.get("iters_per_s")
    return out


def _headline_streaming(p: dict) -> dict:
    out = dict(p["summary"])
    for r in p["rows"]:
        out[f"{r['mode']}_ips"] = r["ips"]
        out[f"{r['mode']}_lat_mean_s"] = r["lat_mean_s"]
    for r in p.get("residency", []):     # quantised slot footprint (§15)
        out[f"slot_bytes_{r['tau_dtype']}"] = r["state_bytes_per_slot"]
        out[f"slots_per_gb_{r['tau_dtype']}"] = r["slots_per_gb"]
    return out


HEADLINES: dict[str, Callable[[dict], dict]] = {
    "coldstart": _headline_coldstart,
    "construction": _headline_construction,
    "obs": _headline_obs,
    "quality": _headline_quality,
    "sharded": _headline_sharded,
    "solver": _headline_solver,
    "sparse": _headline_sparse,
    "streaming": _headline_streaming,
}


def headline(name: str, payload: dict) -> dict:
    """Headline numbers for one bench payload; unknown benches get an
    empty headline rather than an error (forward compatibility)."""
    fn = HEADLINES.get(name)
    try:
        return fn(payload) if fn else {}
    except (KeyError, TypeError, IndexError) as e:
        return {"_extract_error": f"{type(e).__name__}: {e}"}


def build_manifest(root: str = ROOT) -> dict:
    """Scan the committed BENCH files and fold them into the manifest
    dict (benches missing on disk are listed as absent, not errors)."""
    benches = {}
    for name, fname in sorted(BENCH_FILES.items()):
        path = os.path.join(root, fname)
        if not os.path.exists(path):
            benches[name] = {"file": fname, "present": False}
            continue
        with open(path) as f:
            payload = json.load(f)
        benches[name] = {
            "file": fname,
            "present": True,
            "unix_time": payload.get("unix_time"),
            "headline": headline(name, payload),
        }
    return {"schema": SCHEMA, "generated_unix": int(time.time()),
            "benches": benches}


def write_manifest(root: str = ROOT, path: Optional[str] = None) -> str:
    path = path or os.path.join(root, MANIFEST_NAME)
    man = build_manifest(root)
    with open(path, "w") as f:
        json.dump(man, f, indent=2)
    return path


def load_manifest(root: str = ROOT) -> dict:
    with open(os.path.join(root, MANIFEST_NAME)) as f:
        return json.load(f)


if __name__ == "__main__":
    out = write_manifest()
    print(f"wrote {out}")
