"""Claim C6 + local-search trajectory: gap-to-optimum on known-optimum
instances (circle: optimum by construction; even-side grid: boustrophedon)
after equal iteration budgets — the sequential reference, the paper's
parallel designs, and MMAS/AS with and without the batched local search
(DESIGN.md §7).

Emits ``BENCH_quality.json`` at the repo root (path resolved against this
file, not the cwd, so running from any directory works) so future PRs have
a quality/perf trajectory to compare against.

    PYTHONPATH=src python benchmarks/quality.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

from repro.core import aco, sequential, tsp

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_quality.json")

# (kind, size, iterations); grid size is the side (n = side^2).
CASES = (("circle", 48, 60), ("circle", 100, 80), ("grid", 8, 60))
SMOKE_CASES = (("circle", 32, 20),)

# Sparse-vs-dense quality gate (DESIGN.md §12): MMAS over candidate pages
# with k = 16/32 must stay within ~2% of dense MMAS under an equal
# iteration budget on n = 256 instances.
SPARSE_CASES = (("circle", 256, 30), ("grid", 16, 30))
SPARSE_SMOKE_CASES = (("circle", 64, 10),)

# Quantised-vs-fp32 quality gate (DESIGN.md §15): MMAS over a bf16/int8
# resident tau must stay within QUANT_GATE_PCT *absolute* percentage
# points of the fp32 run's tour length under an equal budget — on the
# known-optimum instances and one TSPLIB-or-synthetic instance.  The
# gate runs the *converged* configuration (MMAS + iteration-best 2-opt,
# the mmas_2opt row above) and averages each dtype over QUANT_SEEDS:
# without local search the short-budget gap on these sizes is 30-50%,
# and even converged single-seed tour lengths spread ~+-2% — both wider
# than any quantisation effect, so an unaveraged 1% gate would only
# measure seed luck.
QUANT_CASES = (("circle", 256, 30), ("grid", 16, 30),
               ("tsplib:pr152", 152, 50))
QUANT_SMOKE_CASES = (("circle", 64, 10),)
QUANT_SEEDS = tuple(range(6))
QUANT_GATE_PCT = 1.0


def make_instance(kind: str, size: int) -> tsp.TSPInstance:
    if kind == "circle":
        return tsp.circle_instance(size, seed=size)
    if kind == "grid":
        return tsp.grid_instance(size)
    if kind.startswith("tsplib:"):
        name = kind.split(":", 1)[1]
        inst = tsp.find_tsplib(name)
        return inst if inst is not None \
            else tsp.random_instance(size, seed=size)
    raise ValueError(kind)


def configs(iters: int):
    """Named ACO configs under an equal iteration budget."""
    return (
        ("iroulette", aco.ACOConfig(iterations=iters)),
        ("gumbel", aco.ACOConfig(iterations=iters, selection="gumbel")),
        ("nnlist", aco.ACOConfig(iterations=iters, construction="nn_list")),
        ("pallas", aco.ACOConfig(iterations=iters, use_pallas=True)),
        ("mmas", aco.ACOConfig(iterations=iters, variant="mmas",
                               selection="gumbel")),
        # with local search: same budgets, improved tours drive the deposit
        ("mmas_2opt", aco.ACOConfig(iterations=iters, variant="mmas",
                                    selection="gumbel", local_search="2opt",
                                    ls_tours="iteration_best",
                                    ls_rounds=96)),
        ("as_2opt", aco.ACOConfig(iterations=iters, local_search="2opt_oropt",
                                  ls_tours="all", ls_rounds=8)),
    )


def rows(cases=CASES):
    out = []
    for kind, size, iters in cases:
        inst = make_instance(kind, size)
        opt = inst.known_optimum
        assert opt is not None, (kind, size)
        seq = sequential.SequentialAS(inst.distances(), m=inst.n, seed=1)
        seq.run(iters)
        r = {"instance": inst.name, "kind": kind, "n": inst.n,
             "iters": iters, "optimum": opt,
             "seq_gap_pct": 100 * (seq.best_len / opt - 1)}
        for name, cfg in configs(iters):
            t0 = time.perf_counter()
            st = aco.run(inst, cfg)
            r[f"{name}_gap_pct"] = 100 * (float(st.best_len) / opt - 1)
            r[f"{name}_s"] = round(time.perf_counter() - t0, 2)
        out.append(r)
    return out


def sparse_rows(cases=SPARSE_CASES):
    """Dense-vs-sparse MMAS under equal budgets (the 2% quality gate)."""
    out = []
    for kind, size, iters in cases:
        inst = make_instance(kind, size)
        opt = inst.known_optimum
        assert opt is not None, (kind, size)
        base = aco.ACOConfig(iterations=iters, variant="mmas",
                             selection="gumbel", m=64)
        t0 = time.perf_counter()
        dense_len = float(aco.run(inst, base).best_len)
        r = {"instance": inst.name, "kind": kind, "n": inst.n,
             "iters": iters, "optimum": opt,
             "dense_gap_pct": 100 * (dense_len / opt - 1),
             "dense_s": round(time.perf_counter() - t0, 2)}
        for k in (16, 32):
            cfg = dataclasses.replace(base, sparse=True, sparse_k=k)
            t0 = time.perf_counter()
            sp_len = float(aco.run(inst, cfg).best_len)
            r[f"sparse{k}_gap_pct"] = 100 * (sp_len / opt - 1)
            r[f"sparse{k}_vs_dense_pct"] = 100 * (sp_len / dense_len - 1)
            r[f"sparse{k}_s"] = round(time.perf_counter() - t0, 2)
        out.append(r)
    return out


def quant_rows(cases=QUANT_CASES, gate_pct: float = QUANT_GATE_PCT,
               seeds=QUANT_SEEDS):
    """fp32-vs-quantised MMAS under equal budgets (the 1%-absolute gate).

    ``*_vs_fp32_pct`` is the seed-mean tour-length delta relative to the
    fp32 seed-mean; on known-optimum instances the gap-to-optimum per
    dtype rides along.  The gate asserts here (not just in regress.py):
    a quantised store that degrades MMAS quality beyond ``gate_pct``
    absolute is a broken representation, not a perf trade-off.
    """
    out = []
    for kind, size, iters in cases:
        inst = make_instance(kind, size)
        opt = inst.known_optimum
        base = aco.ACOConfig(iterations=iters, variant="mmas",
                             selection="gumbel", m=64,
                             local_search="2opt",
                             ls_tours="iteration_best", ls_rounds=96)

        def mean_len(cfg):
            return sum(
                float(aco.run(inst, cfg,
                              state=aco.init_colony(inst, cfg, seed=s))
                      .best_len)
                for s in seeds) / len(seeds)

        t0 = time.perf_counter()
        fp32_len = mean_len(base)
        r = {"instance": inst.name, "kind": kind, "n": inst.n,
             "iters": iters, "seeds": len(seeds),
             "fp32_s": round(time.perf_counter() - t0, 2)}
        if opt:
            r["optimum"] = opt
            r["fp32_gap_pct"] = 100 * (fp32_len / opt - 1)
        for tau_dtype in ("bf16", "int8"):
            cfg = dataclasses.replace(base, tau_dtype=tau_dtype)
            t0 = time.perf_counter()
            q_len = mean_len(cfg)
            delta = 100 * (q_len / fp32_len - 1)
            r[f"{tau_dtype}_vs_fp32_pct"] = delta
            if opt:
                r[f"{tau_dtype}_gap_pct"] = 100 * (q_len / opt - 1)
            r[f"{tau_dtype}_s"] = round(time.perf_counter() - t0, 2)
            assert delta <= gate_pct, (
                f"{inst.name}: {tau_dtype} MMAS within-budget quality "
                f"degraded {delta:+.2f}% vs fp32 over {len(seeds)} seeds "
                f"(gate: worse by at most {gate_pct}% absolute; better "
                f"is always fine)")
        out.append(r)
    return out


def _print_rows(results):
    hdr = [k for k in results[0] if not k.endswith("_s")]
    print(",".join(hdr))
    for r in results:
        print(",".join(f"{r[k]:.2f}" if isinstance(r.get(k), float)
                       else str(r.get(k, "")) for k in hdr))


def main(cases=CASES, out_path: str | None = None,
         sparse_cases=SPARSE_CASES, quant_cases=QUANT_CASES):
    out_path = out_path or DEFAULT_OUT
    print("quality (gap-to-known-optimum %, equal iteration budget)")
    results = rows(cases)
    _print_rows(results)
    print("sparse quality (dense vs candidate-page MMAS, equal budget)")
    sresults = sparse_rows(sparse_cases)
    _print_rows(sresults)
    print("quantised quality (fp32 vs bf16/int8 resident tau, equal "
          "budget; gate: worse by <= %.1f%% absolute)" % QUANT_GATE_PCT)
    qresults = quant_rows(quant_cases)
    _print_rows(qresults)
    if out_path:
        payload = {
            "benchmark": "quality",
            "schema": 1,
            "unix_time": int(time.time()),
            "rows": results,
            "sparse_rows": sresults,
            "quant_rows": qresults,
        }
        parent = os.path.dirname(os.path.abspath(out_path))
        os.makedirs(parent, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {os.path.abspath(out_path)}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single small case (CI)")
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default: {DEFAULT_OUT})")
    args = ap.parse_args()
    main(SMOKE_CASES if args.smoke else CASES, args.out,
         SPARSE_SMOKE_CASES if args.smoke else SPARSE_CASES,
         QUANT_SMOKE_CASES if args.smoke else QUANT_CASES)
