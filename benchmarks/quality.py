"""Claim C6: solution quality of the parallel designs matches the sequential
code (paper §V: "results are similar to those obtained by the sequential
code"). Gap-to-optimum on circle instances (known optimum by construction)
after equal iteration budgets, plus the sequential reference."""
from __future__ import annotations

import numpy as np

from repro.core import aco, sequential, tsp

CASES = ((48, 60), (100, 80))


def rows(cases=CASES):
    out = []
    for n, iters in cases:
        inst = tsp.circle_instance(n, seed=n)
        opt = inst.known_optimum
        seq = sequential.SequentialAS(inst.distances(), m=n, seed=1)
        seq.run(iters)
        r = {"n": n, "iters": iters, "optimum": opt,
             "seq_gap_pct": 100 * (seq.best_len / opt - 1)}
        for name, cfg in (
            ("iroulette", aco.ACOConfig(iterations=iters)),
            ("gumbel", aco.ACOConfig(iterations=iters, selection="gumbel")),
            ("nnlist", aco.ACOConfig(iterations=iters, construction="nn_list")),
            ("pallas", aco.ACOConfig(iterations=iters, use_pallas=True)),
            ("mmas", aco.ACOConfig(iterations=iters, variant="mmas",
                                   selection="gumbel")),
        ):
            st = aco.run(inst, cfg)
            r[f"{name}_gap_pct"] = 100 * (float(st.best_len) / opt - 1)
        out.append(r)
    return out


def main(cases=CASES):
    print("quality (gap-to-known-optimum %, equal iteration budget)")
    hdr = None
    for r in rows(cases):
        if hdr is None:
            hdr = list(r.keys())
            print(",".join(hdr))
        print(",".join(f"{r[k]:.2f}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))


if __name__ == "__main__":
    main()
