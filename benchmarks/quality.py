"""Claim C6 + local-search trajectory: gap-to-optimum on known-optimum
instances (circle: optimum by construction; even-side grid: boustrophedon)
after equal iteration budgets — the sequential reference, the paper's
parallel designs, and MMAS/AS with and without the batched local search
(DESIGN.md §7).

Emits ``BENCH_quality.json`` at the repo root (path resolved against this
file, not the cwd, so running from any directory works) so future PRs have
a quality/perf trajectory to compare against.

    PYTHONPATH=src python benchmarks/quality.py [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

from repro.core import aco, sequential, tsp

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_quality.json")

# (kind, size, iterations); grid size is the side (n = side^2).
CASES = (("circle", 48, 60), ("circle", 100, 80), ("grid", 8, 60))
SMOKE_CASES = (("circle", 32, 20),)

# Sparse-vs-dense quality gate (DESIGN.md §12): MMAS over candidate pages
# with k = 16/32 must stay within ~2% of dense MMAS under an equal
# iteration budget on n = 256 instances.
SPARSE_CASES = (("circle", 256, 30), ("grid", 16, 30))
SPARSE_SMOKE_CASES = (("circle", 64, 10),)


def make_instance(kind: str, size: int) -> tsp.TSPInstance:
    if kind == "circle":
        return tsp.circle_instance(size, seed=size)
    if kind == "grid":
        return tsp.grid_instance(size)
    raise ValueError(kind)


def configs(iters: int):
    """Named ACO configs under an equal iteration budget."""
    return (
        ("iroulette", aco.ACOConfig(iterations=iters)),
        ("gumbel", aco.ACOConfig(iterations=iters, selection="gumbel")),
        ("nnlist", aco.ACOConfig(iterations=iters, construction="nn_list")),
        ("pallas", aco.ACOConfig(iterations=iters, use_pallas=True)),
        ("mmas", aco.ACOConfig(iterations=iters, variant="mmas",
                               selection="gumbel")),
        # with local search: same budgets, improved tours drive the deposit
        ("mmas_2opt", aco.ACOConfig(iterations=iters, variant="mmas",
                                    selection="gumbel", local_search="2opt",
                                    ls_tours="iteration_best",
                                    ls_rounds=96)),
        ("as_2opt", aco.ACOConfig(iterations=iters, local_search="2opt_oropt",
                                  ls_tours="all", ls_rounds=8)),
    )


def rows(cases=CASES):
    out = []
    for kind, size, iters in cases:
        inst = make_instance(kind, size)
        opt = inst.known_optimum
        assert opt is not None, (kind, size)
        seq = sequential.SequentialAS(inst.distances(), m=inst.n, seed=1)
        seq.run(iters)
        r = {"instance": inst.name, "kind": kind, "n": inst.n,
             "iters": iters, "optimum": opt,
             "seq_gap_pct": 100 * (seq.best_len / opt - 1)}
        for name, cfg in configs(iters):
            t0 = time.perf_counter()
            st = aco.run(inst, cfg)
            r[f"{name}_gap_pct"] = 100 * (float(st.best_len) / opt - 1)
            r[f"{name}_s"] = round(time.perf_counter() - t0, 2)
        out.append(r)
    return out


def sparse_rows(cases=SPARSE_CASES):
    """Dense-vs-sparse MMAS under equal budgets (the 2% quality gate)."""
    out = []
    for kind, size, iters in cases:
        inst = make_instance(kind, size)
        opt = inst.known_optimum
        assert opt is not None, (kind, size)
        base = aco.ACOConfig(iterations=iters, variant="mmas",
                             selection="gumbel", m=64)
        t0 = time.perf_counter()
        dense_len = float(aco.run(inst, base).best_len)
        r = {"instance": inst.name, "kind": kind, "n": inst.n,
             "iters": iters, "optimum": opt,
             "dense_gap_pct": 100 * (dense_len / opt - 1),
             "dense_s": round(time.perf_counter() - t0, 2)}
        for k in (16, 32):
            cfg = dataclasses.replace(base, sparse=True, sparse_k=k)
            t0 = time.perf_counter()
            sp_len = float(aco.run(inst, cfg).best_len)
            r[f"sparse{k}_gap_pct"] = 100 * (sp_len / opt - 1)
            r[f"sparse{k}_vs_dense_pct"] = 100 * (sp_len / dense_len - 1)
            r[f"sparse{k}_s"] = round(time.perf_counter() - t0, 2)
        out.append(r)
    return out


def _print_rows(results):
    hdr = [k for k in results[0] if not k.endswith("_s")]
    print(",".join(hdr))
    for r in results:
        print(",".join(f"{r[k]:.2f}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))


def main(cases=CASES, out_path: str | None = None,
         sparse_cases=SPARSE_CASES):
    out_path = out_path or DEFAULT_OUT
    print("quality (gap-to-known-optimum %, equal iteration budget)")
    results = rows(cases)
    _print_rows(results)
    print("sparse quality (dense vs candidate-page MMAS, equal budget)")
    sresults = sparse_rows(sparse_cases)
    _print_rows(sresults)
    if out_path:
        payload = {
            "benchmark": "quality",
            "schema": 1,
            "unix_time": int(time.time()),
            "rows": results,
            "sparse_rows": sresults,
        }
        parent = os.path.dirname(os.path.abspath(out_path))
        os.makedirs(parent, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {os.path.abspath(out_path)}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single small case (CI)")
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default: {DEFAULT_OUT})")
    args = ap.parse_args()
    main(SMOKE_CASES if args.smoke else CASES, args.out,
         SPARSE_SMOKE_CASES if args.smoke else SPARSE_CASES)
