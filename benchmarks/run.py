"""Benchmark entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,...]

Prints CSV blocks per table. --full uses the paper's larger instances
(minutes on one CPU core); default sizes keep the whole suite ~2-4 min.
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (construction_profile, fig4_overall, fig5_pheromone,
               local_search, manifest, obs_overhead, quality, roofline,
               sharded_throughput, solver_throughput, sparse_scale,
               streaming_throughput, table2_tour_construction,
               table3_pheromone)

TABLES = {
    "table2": lambda full: table2_tour_construction.main(
        table2_tour_construction.FULL_SIZES if full
        else table2_tour_construction.SIZES),
    "table3": lambda full: table3_pheromone.main(
        table3_pheromone.FULL_SIZES if full else table3_pheromone.SIZES),
    "fig4": lambda full: fig4_overall.main(
        fig4_overall.FULL_SIZES if full else fig4_overall.SIZES),
    "fig5": lambda full: fig5_pheromone.main(fig5_pheromone.SIZES),
    "quality": lambda full: quality.main(),
    "local_search": lambda full: local_search.main(
        local_search.FULL_SIZES if full else local_search.SIZES),
    "construction": lambda full: construction_profile.main(
        construction_profile.FULL_SIZES if full
        else construction_profile.SIZES),
    "solver": lambda full: solver_throughput.main(
        solver_throughput.CASES if full else solver_throughput.SMOKE_CASES),
    "streaming": lambda full: streaming_throughput.main(
        streaming_throughput.CASE if full
        else streaming_throughput.SMOKE_CASE),
    "sharded": lambda full: sharded_throughput.main(
        sharded_throughput.CASE if full
        else sharded_throughput.SMOKE_CASE),
    "roofline": lambda full: roofline.main(),
    "sparse": lambda full: sparse_scale.main(
        sparse_scale.CASES if full else sparse_scale.DRY_CASES,
        out_path=sparse_scale.DEFAULT_OUT if full else None),
    "obs": lambda full: obs_overhead.main(
        obs_overhead.CASE if full else obs_overhead.SMOKE_CASE,
        out_path=obs_overhead.DEFAULT_OUT if full else None),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(TABLES))
    ap.add_argument("--no-manifest", action="store_true",
                    help="skip refreshing BENCH_manifest.json at the end")
    args = ap.parse_args()
    names = list(TABLES) if not args.only else args.only.split(",")
    for name in names:
        if name not in TABLES:
            print(f"unknown table {name}", file=sys.stderr)
            continue
        t0 = time.time()
        print(f"==== {name} " + "=" * 50)
        TABLES[name](args.full)
        print(f"---- {name} done in {time.time()-t0:.1f}s\n", flush=True)
    if not args.no_manifest:
        # fold whatever BENCH_*.json files now exist into the manifest so
        # benchmarks/regress.py sees a consistent index (DESIGN.md §14)
        print(f"manifest refreshed: {manifest.write_manifest()}")


if __name__ == "__main__":
    main()
