"""First-request cold-start latency: jit-on-demand vs persistent cache vs warmup.

The serving cold-start problem (DESIGN.md §16): the first request that
needs a (bucket, batch, config) program pays the full XLA compile on the
serving critical path.  This bench measures the first-request latency of a
streaming service under the three mitigation levels solver/programs.py
provides, each trial in a **fresh subprocess** so the in-process jit cache
really is cold:

- ``cold``     plain service: the first request compiles the chunk program;
- ``persist``  persistent XLA compilation cache (pre-primed directory):
               the compile is replaced by an executable cache load;
- ``warmed``   ``warm_programs`` AOT-compiles the bucket before the
               request: the request dispatches a cached executable.

The headline is the p99 over ``--repeats`` trials per mode and the
``warmed_over_cold`` ratio, floor-asserted (a warmed first request must be
at most ``--max-ratio`` of the cold one — the whole point of the warmup
ladder) and regression-guarded via benchmarks/regress.py.

Emits ``BENCH_coldstart.json`` at the repo root.

    PYTHONPATH=src python benchmarks/coldstart.py [--smoke|--dry]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_coldstart.json")

CASE = dict(n=24, batch=4, chunk=3, iterations=6, variant="mmas", seed=0,
            repeats=3, max_ratio=0.5)
# --dry/--smoke: one repeat, looser floor (single-sample wall clock on a
# loaded CI container) — still proves warmed < cold by a wide margin.
SMOKE_CASE = dict(n=24, batch=4, chunk=3, iterations=6, variant="mmas",
                  seed=0, repeats=1, max_ratio=0.8)


def _child(case: dict, mode: str, cache_dir: str) -> dict:
    """One trial, run inside this (fresh) process: build the service,
    apply the mode's mitigation, then time the first request end to end
    (submit -> result).  Prints one JSON line on stdout."""
    t_import0 = time.perf_counter()
    from repro.core import aco, tsp
    from repro.solver import (ProgramCache, StreamingSolverService,
                              enable_persistent_cache)
    import_s = time.perf_counter() - t_import0

    if mode == "persist":
        enable_persistent_cache(cache_dir)
    cfg = aco.ACOConfig(variant=case["variant"],
                        iterations=case["iterations"], seed=case["seed"])
    programs = ProgramCache() if mode == "warmed" else None
    svc = StreamingSolverService(cfg, max_batch=case["batch"],
                                 chunk=case["chunk"], programs=programs)
    warm_s = 0.0
    if mode == "warmed":
        t0 = time.perf_counter()
        svc.warm_programs(case["n"], case["n"])
        warm_s = time.perf_counter() - t0

    inst = tsp.random_instance(case["n"], seed=case["seed"])
    t0 = time.perf_counter()
    svc.submit(inst, iterations=case["iterations"], seed=case["seed"])
    results = svc.run_until_drained()
    first_request_s = time.perf_counter() - t0
    assert len(results) == 1 and np.isfinite(results[0].best_len)
    return {"mode": mode, "first_request_s": first_request_s,
            "warm_s": warm_s, "import_s": import_s,
            "best_len": float(results[0].best_len),
            "hits": programs.stats()["hits"] if programs else 0}


def _spawn(case: dict, mode: str, cache_dir: str) -> dict:
    """Run one trial in a fresh interpreter (cold in-process jit cache)."""
    payload = json.dumps({"case": case, "mode": mode,
                          "cache_dir": cache_dir})
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), env.get("PYTHONPATH", "")])
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", payload],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(f"coldstart child ({mode}) failed:\n"
                           f"{out.stdout}\n{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def _percentiles(samples: list[float]) -> dict:
    a = np.asarray(samples, np.float64)
    return {"p50_s": float(np.percentile(a, 50)),
            "p99_s": float(np.percentile(a, 99)),
            "mean_s": float(a.mean()), "samples": [round(s, 4)
                                                   for s in samples]}


def main(case: dict, out_path: str = DEFAULT_OUT) -> dict:
    cache_dir = tempfile.mkdtemp(prefix="coldstart_xla_")
    # Prime the persistent cache once (this run's compile populates the
    # directory; it is *not* timed as a persist sample).
    _spawn(case, "persist", cache_dir)

    rows = {}
    for mode in ("cold", "persist", "warmed"):
        trials = [_spawn(case, mode, cache_dir)
                  for _ in range(case["repeats"])]
        rows[mode] = _percentiles([t["first_request_s"] for t in trials])
        rows[mode]["warm_s_mean"] = float(
            np.mean([t["warm_s"] for t in trials]))
        print(f"coldstart: {mode:8s} first-request "
              f"p99={rows[mode]['p99_s']:.3f}s "
              f"(p50={rows[mode]['p50_s']:.3f}s)", file=sys.stderr)

    warmed_over_cold = rows["warmed"]["p99_s"] / rows["cold"]["p99_s"]
    persist_over_cold = rows["persist"]["p99_s"] / rows["cold"]["p99_s"]
    payload = {
        "schema": "repro.bench_coldstart/v1",
        "unix_time": int(time.time()),
        "case": case,
        "rows": rows,
        "warmed_over_cold": warmed_over_cold,
        "persist_over_cold": persist_over_cold,
        "max_ratio_required": case["max_ratio"],
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"coldstart: warmed/cold={warmed_over_cold:.3f} "
          f"persist/cold={persist_over_cold:.3f} -> {out_path}",
          file=sys.stderr)
    # The floor assertion: a warmup ladder that doesn't beat cold-start
    # compile latency is a regression in the tentpole claim itself.
    assert warmed_over_cold <= case["max_ratio"], (
        f"warmed first-request p99 is {warmed_over_cold:.2f}x cold "
        f"(required <= {case['max_ratio']})")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single-repeat quick case")
    ap.add_argument("--dry", action="store_true",
                    help="CI smoke: single repeat, write to a temp file "
                         "(the committed BENCH file is untouched)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--child", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child:
        spec = json.loads(args.child)
        print(json.dumps(_child(spec["case"], spec["mode"],
                                spec["cache_dir"])))
        sys.exit(0)
    case = SMOKE_CASE if (args.smoke or args.dry) else CASE
    out = args.out or (os.path.join(tempfile.mkdtemp(prefix="coldstart_"),
                                    "BENCH_coldstart.json")
                       if args.dry else DEFAULT_OUT)
    main(case, out)
