"""Paper Fig. 5: pheromone-update speed-up vs the sequential code.

Sequential: SequentialAS.update_pheromone (numpy loops over ants).
Accelerated: best JAX strategy (scatter) and the fused Pallas kernel.
Claim: speed-up grows ~linearly with problem size (data-parallel pattern).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aco, pheromone, sequential, strategies, tsp
from repro.kernels import ops as kops

from .timing import time_fn, time_host_fn

SIZES = (48, 100, 280, 442)


def rows(sizes=SIZES):
    out = []
    for n in sizes:
        inst = tsp.random_instance(n, seed=n)
        d = inst.distances()
        seq = sequential.SequentialAS(d, m=n, seed=0)
        tours, lengths = seq.construct()
        seq_ms = time_host_fn(seq.update_pheromone, tours, lengths, iters=3)

        tau = jnp.asarray(seq.tau, jnp.float32)
        jt = jnp.asarray(tours)
        w = jnp.asarray(1.0 / lengths, jnp.float32)
        scatter_ms = time_fn(
            jax.jit(lambda t: pheromone.update(t, jt, w, 0.5, "scatter")),
            tau, warmup=1, iters=3)
        # interpret-mode Pallas is Python-speed: only time it at small n
        # (structural comparison; real-TPU numbers come from the kernel).
        pallas_ms = (time_fn(lambda t: kops.pheromone_update(t, jt, w, 0.5),
                             tau, warmup=1, iters=3) if n <= 100 else
                     float("nan"))
        out.append({
            "n": n, "seq_ms": seq_ms, "jax_scatter_ms": scatter_ms,
            "pallas_fused_ms": pallas_ms,
            "fig5_speedup": seq_ms / scatter_ms,
        })
    return out


def main(sizes=SIZES):
    print("fig5_pheromone (ms per pheromone update; speedup vs sequential)")
    hdr = None
    for r in rows(sizes):
        if hdr is None:
            hdr = list(r.keys())
            print(",".join(hdr))
        print(",".join(f"{r[k]:.2f}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))


if __name__ == "__main__":
    main()
