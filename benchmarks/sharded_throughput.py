"""Sharded solver throughput: instances/sec vs device count (DESIGN.md §11).

One compute-bound bucket (B instances padded to one power-of-two bucket,
uniform budgets) is driven through ``engine.run_batch`` with a 1-D data
mesh of D in {1, 2, 4, 8} devices.  Because the machine running this is a
CPU host, the sweep executes in a **subprocess** with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — 8 host devices
with the *same* flags for every D, so the comparison isolates
instance-axis sharding from thread-pool configuration; the parent process
(and any test session importing this module) keeps its 1-device view.

Timing discipline (this container's wall clock varies up to ~3x between
runs): every (D) program is compile-warmed first, then timed best-of-REPS
from freshly initialised states.  Emits ``BENCH_sharded.json`` at the
repo root: one row per device count plus the D=8 vs D=1 speedup.

    PYTHONPATH=src python benchmarks/sharded_throughput.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_sharded.json")

DEVICE_COUNTS = (1, 2, 4, 8)
# Compute-bound on a small host: bucket 64 is too small for XLA:CPU
# intra-op threading to split one instance's matrices, so the instance
# axis is the only exploitable parallelism — exactly what the placement
# layer shards.  (At bucket >= 128 intra-op threads already serve D=1 and
# the sharding win shrinks; that regime needs real accelerators.)
CASE = dict(batch=8, n=56, iters=25, reps=3, seed=0)
SMOKE_CASE = dict(batch=8, n=56, iters=8, reps=2, seed=0)

_WORKER = r"""
import json, time, sys
import jax, jax.numpy as jnp
from repro.core import aco, tsp
from repro.solver import batch as bm, engine, placement

case = json.loads(sys.argv[1])
B, n, iters, reps = case["batch"], case["n"], case["iters"], case["reps"]
insts = [tsp.random_instance(n, seed=case["seed"] + i) for i in range(B)]
cfg = aco.ACOConfig(iterations=iters, selection="gumbel")
b = bm.make_batch(insts, None, cfg.nn_k)
budgets = jnp.asarray([iters] * B, jnp.int32)
seeds = list(range(B))
rows = []
for d in case["device_counts"]:
    mesh = placement.data_mesh(d)
    s = engine.init_states(insts, cfg, seeds, b.n_pad)
    out, _ = engine.run_batch(b.problem, s, budgets, cfg, iters,
                              mesh=mesh)                      # compile warm
    out.best_len.block_until_ready()
    best = float("inf")
    for _ in range(reps):
        s = engine.init_states(insts, cfg, seeds, b.n_pad)
        jax.block_until_ready(s)
        t0 = time.perf_counter()
        out, _ = engine.run_batch(b.problem, s, budgets, cfg, iters,
                                  mesh=mesh)
        out.best_len.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    rows.append({"devices": d, "wall_s": round(best, 4),
                 "ips": round(B / best, 3)})
print("ROWS" + json.dumps(rows))
"""


def run_sweep(case: dict) -> list[dict]:
    case = dict(case, device_counts=list(DEVICE_COUNTS))
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={max(DEVICE_COUNTS)}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", _WORKER, json.dumps(case)],
        capture_output=True, text=True, env=env, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"sharded sweep worker failed:\n"
                           f"{out.stderr[-4000:]}")
    line = [l for l in out.stdout.splitlines() if l.startswith("ROWS")][-1]
    return json.loads(line[len("ROWS"):])


def main(case: dict = CASE, out_path: str = DEFAULT_OUT) -> dict:
    from repro.solver import batch as bm
    rows = run_sweep(case)
    by_d = {r["devices"]: r for r in rows}
    d_lo, d_hi = min(DEVICE_COUNTS), max(DEVICE_COUNTS)
    speedup = by_d[d_hi]["ips"] / by_d[d_lo]["ips"]
    report = {
        "case": {k: case[k] for k in ("batch", "n", "iters", "reps")},
        "bucket": bm.bucket_size(case["n"]),
        "rows": rows,
        f"speedup_{d_hi}v{d_lo}": round(speedup, 3),
    }
    print("devices,wall_s,ips")
    for r in rows:
        print(f"{r['devices']},{r['wall_s']},{r['ips']}")
    print(f"# D={d_hi} vs D={d_lo} speedup: {speedup:.2f}x")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_path}")
    # Generous floor (the container's wall clock is noisy; the measured
    # headroom is ~1.7x): sharding must never *lose* to one device.
    assert speedup >= 1.15, f"sharded speedup regressed: {speedup:.2f}x"
    return report


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    main(SMOKE_CASE if args.smoke else CASE, args.out)
