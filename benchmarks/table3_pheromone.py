"""Paper Tables III/IV: pheromone-update strategy ladder.

Claims under test: C4 (scatter-to-gather is orders of magnitude worse than
the scatter/atomic-analogue, growing with n) and C5 (tiling / symmetric
reduction improve s2g but not its order of magnitude). Adds the TPU-native
one-hot-MXU deposit and the fused Pallas kernel — the beyond-paper rows that
invert the paper's conclusion on this hardware (DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aco, pheromone, strategies, tsp
from repro.kernels import ops as kops

from .timing import time_fn

SIZES = (48, 100, 280)
FULL_SIZES = (48, 100, 280, 442)


def _tours(n: int):
    inst = tsp.random_instance(n, seed=n)
    prob = aco.make_problem(inst, 8)
    tau0 = aco.initial_tau(inst, aco.ACOConfig())
    tau = jnp.full((n, n), tau0, jnp.float32)
    ci = strategies.choice_matrix(tau, prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(jax.random.PRNGKey(3), prob.dist, ci, n)
    w = 1.0 / res.lengths
    return tau, res.tours, w


def rows(sizes=SIZES):
    out = []
    for n in sizes:
        tau, tours, w = _tours(n)
        upd = lambda strat: time_fn(
            jax.jit(lambda t: pheromone.update(t, tours, w, 0.5,
                                               strategy=strat)), tau,
            warmup=1, iters=3)
        r = {"n": n}
        # 1/2. atomic + shared-memory analogue: XLA scatter-add
        r["v1_scatter_atomic"] = upd("scatter")
        # 3. Instruction & thread Reduction (symmetry, half the updates)
        r["v3_reduction"] = upd("reduction")
        # 4. scatter-to-gather + tiling
        r["v4_s2g_tiled"] = upd("s2g_tiled")
        # 5. scatter-to-gather (honest O(n^4))
        r["v5_s2g"] = upd("s2g")
        # ours: one-hot MXU deposit, and the fused Pallas kernel
        # (interpret mode = Python speed; timed at small n for structure only)
        r["ours_onehot"] = upd("onehot")
        r["ours_pallas_fused"] = (time_fn(
            lambda t: kops.pheromone_update(t, tours, w, 0.5), tau,
            warmup=1, iters=3) if n <= 100 else float("nan"))
        r["slowdown_s2g_vs_atomic"] = r["v5_s2g"] / r["v1_scatter_atomic"]
        out.append(r)
    return out


def main(sizes=SIZES):
    print("table3_pheromone (ms per pheromone update, m=n ants)")
    hdr = None
    for r in rows(sizes):
        if hdr is None:
            hdr = list(r.keys())
            print(",".join(hdr))
        print(",".join(f"{r[k]:.2f}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))


if __name__ == "__main__":
    main()
