"""Paper Table II: tour-construction strategy ladder.

Reproduces the paper's code-version ladder on CPU-JAX (one iteration of m=n
ants). GPU-memory-placement versions (5/6: shared/texture) have no TPU/JAX
analogue — the nearest mapping is noted per row. The paper's claims under
test: C1 (data-parallel >> task-parallel), C2 (choice precompute win),
C3 (NN-list win).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aco, strategies, tsp

from .timing import time_fn

SIZES = (48, 100, 280, 442)
FULL_SIZES = (48, 100, 280, 442, 657, 1002)


def _mk(n: int):
    inst = tsp.random_instance(n, seed=n)
    prob = aco.make_problem(inst, nn_k=min(30, n - 1))
    cfg = aco.ACOConfig()
    tau0 = aco.initial_tau(inst, cfg)
    tau = jnp.full((n, n), tau0, jnp.float32)
    ci = strategies.choice_matrix(tau, prob.eta, 1.0, 2.0)
    return inst, prob, tau, ci


def _construct(prob, ci, tau, m, method, selection="iroulette"):
    key = jax.random.PRNGKey(7)

    def run(k):
        return strategies.construct_tours(
            k, prob.dist, ci, m, method=method, selection=selection,
            nn=prob.nn, tau=tau, eta=prob.eta)

    return time_fn(run, key, warmup=1, iters=3)


def rows(sizes=SIZES):
    out = []
    for n in sizes:
        inst, prob, tau, ci = _mk(n)
        m = n
        r = {"n": n}
        # 1. task-based, recompute heuristic each step (paper baseline)
        r["v1_task_baseline"] = _construct(prob, ci, tau, m, "task_baseline")
        # 2. + Choice kernel (precompute tau^a*eta^b)
        r["v2_choice"] = _construct(prob, ci, tau, m, "task_choice",
                                    selection="roulette")
        # 3. device-side RNG: jax.random is already device-side; = v2 (noted)
        # 4. NN-list
        r["v4_nnlist"] = _construct(prob, ci, tau, m, "nn_list")
        # 7. data parallelism (paper's contribution): I-Roulette reduction
        r["v7_data_parallel"] = _construct(prob, ci, tau, m, "data_parallel")
        # 8. + Pallas tour_select kernel (VMEM-tiled fused selection;
        #    interpret mode on CPU — structural row, real perf needs TPU)
        r["v8_data_parallel_pallas"] = (
            _construct(prob, ci, tau, m, "pallas") if n <= 100
            else float("nan"))
        r["total_speedup_v1_over_v7"] = r["v1_task_baseline"] / r["v7_data_parallel"]
        out.append(r)
    return out


def main(sizes=SIZES):
    print("table2_tour_construction (ms per AS iteration's construction)")
    hdr = None
    for r in rows(sizes):
        if hdr is None:
            hdr = list(r.keys())
            print(",".join(hdr))
        print(",".join(f"{r[k]:.2f}" if isinstance(r[k], float) else str(r[k])
                       for k in hdr))


if __name__ == "__main__":
    main()
