"""Telemetry overhead: streaming throughput at three observability levels.

The acceptance bar for the telemetry fabric (DESIGN.md §13) is that full
telemetry costs ~nothing: the in-jit metrics are a handful of reductions
fused into an already-compiled chunk program, and the host-side events /
spans are bounded deque appends.  This benchmark replays the same Poisson
arrival trace through the StreamingSolverService at:

- ``off``     metrics off, in-memory telemetry only (the always-on
              bounded instruments every service run pays — the baseline);
- ``events``  metrics off, plus the JSON-lines event log mirrored to a
              file as records arrive (the --events-out path);
- ``full``    ``cfg.metrics=True`` (in-jit StepMetrics rows ride the
              resident state, every result carries a metrics row) plus
              the event-log file mirror and periodic stats snapshots;
- ``serving`` everything in ``full`` plus the serving observability
              plane (DESIGN.md §14): per-request tenant labels feeding
              the SLO tracker, and a live ``/metrics`` endpoint being
              scraped concurrently while the trace replays.

Each level replays best-of-``REPS`` (min wall) to damp scheduler noise;
the summary reports full/off and serving/off throughput and whether
each holds the <=5% overhead bar.  Emits ``BENCH_obs.json`` at the repo
root.

    PYTHONPATH=src python benchmarks/obs_overhead.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import threading
import time
import urllib.request

import numpy as np

from repro import obs
from repro.core import aco
from repro.solver import StreamingSolverService, streaming

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_obs.json")

CASE = dict(bucket=32, slots=4, requests=24, min_n=17, max_n=32,
            iters=(4, 4, 4, 24) * 5 + (4,) * 4, chunk=4, seed=0,
            pressure=0.2)
SMOKE_CASE = dict(bucket=32, slots=4, requests=12, min_n=17, max_n=32,
                  iters=(3, 3, 3, 15) * 2 + (3,) * 4, chunk=3, seed=0,
                  pressure=0.2)

REPS = 3
LEVELS = ("off", "events", "full", "serving")
TENANTS = ("tenant-a", "tenant-b")
SCRAPE_EVERY_S = 0.05


def _make_trace(case, rate: float) -> list[streaming.TraceItem]:
    return streaming.make_poisson_trace(
        case["requests"], rate, case["min_n"], case["max_n"],
        seed=case["seed"], iterations=case["iters"])


def _cfg(case, level: str) -> aco.ACOConfig:
    return aco.ACOConfig(iterations=max(case["iters"]), selection="gumbel",
                         metrics=(level in ("full", "serving")))


def _service(case, level: str, events_path: str) -> StreamingSolverService:
    tel = obs.Telemetry(
        events_path=events_path if level != "off" else None)
    return StreamingSolverService(
        _cfg(case, level), max_batch=case["slots"],
        min_bucket=case["bucket"], chunk=case["chunk"], telemetry=tel,
        snapshot_every=0.05 if level in ("full", "serving") else 0.0)


def _scraper(url: str, stop: threading.Event) -> threading.Thread:
    """Background thread hammering ``/metrics`` while the trace replays,
    so the serving level pays realistic concurrent-scrape cost."""
    def loop():
        while not stop.is_set():
            try:
                urllib.request.urlopen(url, timeout=1.0).read()
            except OSError:
                pass
            stop.wait(SCRAPE_EVERY_S)
    t = threading.Thread(target=loop, name="obs-bench-scraper", daemon=True)
    t.start()
    return t


def _warm(case, tmp: str) -> float:
    """Compile-warm both chunk programs (metrics on and off are distinct
    compiled shapes) and return the busy wall time for rate calibration."""
    probe = _make_trace(case, rate=1e9)
    busy = None
    for level in ("off", "full"):
        svc = _service(case, level, os.path.join(tmp, f"warm_{level}.jsonl"))
        for k, t in enumerate(probe):
            svc.submit(t.instance, iterations=t.iterations, seed=t.seed)
        t0 = time.perf_counter()
        svc.run_until_drained()
        wall = time.perf_counter() - t0
        if level == "off":
            busy = wall
        svc.tel.close()
    return busy


def run_case(case) -> list[dict]:
    tmp = tempfile.mkdtemp(prefix="obs_overhead_")
    busy_s = _warm(case, tmp)
    rate = case["requests"] / max(case["pressure"] * busy_s, 1e-3)
    trace = _make_trace(case, rate)

    # serving level: identical instances/seeds/budgets, plus tenant
    # labels (pure observability metadata — results must not change)
    serving_trace = [dataclasses.replace(t, tenant=TENANTS[i % len(TENANTS)])
                     for i, t in enumerate(trace)]

    rows = []
    for level in LEVELS:
        best = None
        for rep in range(REPS):
            svc = _service(case, level,
                           os.path.join(tmp, f"{level}_{rep}.jsonl"))
            server = stop = None
            if level == "serving":
                server = obs.MetricsServer(svc.tel, health_fn=svc.health,
                                           port=0)
                stop = threading.Event()
                _scraper(server.url("/metrics"), stop)
            t0 = time.perf_counter()
            res = streaming.replay_trace(
                svc, serving_trace if level == "serving" else trace)
            wall = time.perf_counter() - t0
            if server is not None:
                stop.set()
                server.close()
            svc.tel.close()
            assert len(res) == case["requests"]
            if level in ("full", "serving"):
                assert all(r.metrics is not None for r in res)
            if best is None or wall < best[1]:
                best = (res, wall, svc.stats["occupancy_mean"])
        res, wall, occ = best
        lat = [r.latency_s for r in res]
        rows.append({
            "level": level, "requests": len(res),
            "wall_s": round(wall, 4),
            "ips": round(len(res) / wall, 3),
            "lat_mean_s": round(float(np.mean(lat)), 4),
            "lat_p95_s": round(float(np.percentile(lat, 95)), 4),
            "occupancy_mean": round(occ, 4),
        })
    return rows


def main(case=CASE, out_path: str | None = DEFAULT_OUT):
    print("telemetry overhead on the streaming service "
          f"(bucket={case['bucket']}, slots={case['slots']}, "
          f"requests={case['requests']})")
    rows = run_case(case)
    hdr = list(rows[0])
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r[k]) for k in hdr))
    off = next(r for r in rows if r["level"] == "off")
    full = next(r for r in rows if r["level"] == "full")
    serving = next(r for r in rows if r["level"] == "serving")
    ratio = full["ips"] / off["ips"]
    sratio = serving["ips"] / off["ips"]
    summary = {
        "full_vs_off_ips": round(ratio, 4),
        "overhead_pct": round(100.0 * (1.0 - ratio), 2),
        "within_5pct": ratio >= 0.95,
        "serving_vs_off_ips": round(sratio, 4),
        "serving_overhead_pct": round(100.0 * (1.0 - sratio), 2),
        "within_5pct_serving": sratio >= 0.95,
    }
    print(f"full/off throughput: {summary['full_vs_off_ips']}x "
          f"({summary['overhead_pct']}% overhead; "
          f"<=5% bar {'held' if summary['within_5pct'] else 'MISSED'})")
    print(f"serving/off throughput: {summary['serving_vs_off_ips']}x "
          f"({summary['serving_overhead_pct']}% overhead; "
          f"<=5% bar {'held' if summary['within_5pct_serving'] else 'MISSED'})")
    if out_path:
        payload = {
            "benchmark": "obs_overhead",
            "schema": 1,
            "unix_time": int(time.time()),
            "case": {k: v for k, v in case.items()},
            "rows": rows,
            "summary": summary,
        }
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {os.path.abspath(out_path)}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small fast case")
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default: {DEFAULT_OUT})")
    args = ap.parse_args()
    main(SMOKE_CASE if args.smoke else CASE, args.out or DEFAULT_OUT)
