"""Shared benchmark timing helpers (block_until_ready, warmup, best-of-k)."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kwargs) -> float:
    """Median wall-time in milliseconds of fn(*args) with device sync."""
    for _ in range(warmup):
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e3


def time_host_fn(fn: Callable, *args, warmup: int = 0, iters: int = 3,
                 **kwargs) -> float:
    """Median wall-time (ms) of a host (numpy) function."""
    for _ in range(warmup):
        fn(*args, **kwargs)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args, **kwargs)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e3
