"""Paper-scale sparse TSP: the O(n*k) paged route past the O(n^2) wall.

The dense pipeline keeps three resident (n, n) float32 tensors per colony;
at the paper's pr2392 ceiling that is ~69 MB per colony before a single
transient. The sparse route (DESIGN.md §12) holds O(n*k) pages instead.
This benchmark runs MMAS over candidate pages on pr1002/pr2392 (real
TSPLIB files when present under ``examples/``, synthetic same-size
instances otherwise — no network fetch) for >= 10 full
construction+update iterations, both the standard data-parallel
construction and the Partial-ACO window-mutation route, and emits the
resident-bytes O(n*k)-vs-O(n^2) table plus iters/sec to
``BENCH_sparse.json``.

Ant count is fixed (not m = n): at this scale the per-step transients are
(m, n) and the point of the route is that *nothing* resident or transient
is (n, n)-shaped.

    PYTHONPATH=src python benchmarks/sparse_scale.py [--dry] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.core import aco, quant, tsp
from repro.sparse import aco as sparse_aco
from repro.sparse import store

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_sparse.json")

# (tsplib name, n, candidate width k)
CASES = (("pr1002", 1002, 16), ("pr2392", 2392, 16))
DRY_CASES = (("dry128", 128, 8),)

ITERS = 10          # acceptance floor: >= 10 construction+update iterations
ANTS = 64
WINDOW = 64         # Partial-ACO rebuild window


def get_instance(name: str, n: int) -> tuple[tsp.TSPInstance, str]:
    """Real TSPLIB fixture when present, else synthetic of the same size."""
    inst = tsp.find_tsplib(name)
    if inst is not None:
        return inst, "tsplib"
    return tsp.random_instance(n, seed=n), "synthetic"


def bench_case(name: str, n: int, k: int, construction: str,
               iters: int = ITERS, tau_dtype: str = "fp32") -> dict:
    inst, source = get_instance(name, n)
    cfg = aco.ACOConfig(variant="mmas", selection="iroulette", sparse=True,
                        sparse_k=k, m=ANTS, iterations=iters, seed=0,
                        construction=construction, partial_window=WINDOW,
                        tau_dtype=tau_dtype)
    ewt = inst.edge_weight_type
    t0 = time.perf_counter()
    problem = store.make_sparse_problem(inst, k)
    state = sparse_aco.init_sparse_colony(inst, cfg)
    build_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    state, _ = sparse_aco.sparse_colony_step(problem, state, cfg, ewt)
    state.best_len.block_until_ready()
    compile_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    for _ in range(iters - 1):
        state, _ = sparse_aco.sparse_colony_step(problem, state, cfg, ewt)
    state.best_len.block_until_ready()
    steady_s = time.perf_counter() - t0

    res = store.resident_bytes(problem, state)
    dense = store.dense_resident_bytes(inst.n)
    tau_bytes = (quant.tau_nbytes(state.tau)
                 + quant.tau_nbytes(state.ovf_tau))
    return {
        "instance": inst.name, "source": source, "n": inst.n, "k": k,
        "m": ANTS, "construction": construction, "iters": iters,
        "tau_dtype": tau_dtype,
        "best_len": round(float(state.best_len), 2),
        "resident_bytes_sparse": res,
        "resident_bytes_dense": dense,
        "resident_tau_bytes": tau_bytes,
        "dense_over_sparse": round(dense / res, 1),
        "build_s": round(build_s, 2),
        "compile_s": round(compile_s, 2),
        "iters_per_s": round((iters - 1) / max(steady_s, 1e-9), 3),
    }


def main(cases=CASES, out_path: str | None = DEFAULT_OUT):
    print("sparse scale (MMAS over candidate pages, no (n, n) tensor)")
    rows = []
    for name, n, k in cases:
        for construction in ("data_parallel", "partial"):
            rows.append(bench_case(name, n, k, construction))
        # quantised resident tau (DESIGN.md §15): same case through the
        # data-parallel route per tau_dtype — residency + throughput rows
        fp32_tau = rows[-2]["resident_tau_bytes"]   # data_parallel row
        for tau_dtype in ("bf16", "int8"):
            r = bench_case(name, n, k, "data_parallel", tau_dtype=tau_dtype)
            r["tau_fp32_over_quant"] = round(
                fp32_tau / r["resident_tau_bytes"], 2)
            rows.append(r)
    hdr = list(rows[-1])
    print(",".join(hdr))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in hdr))
    if out_path:
        payload = {
            "benchmark": "sparse_scale",
            "schema": 1,
            "unix_time": int(time.time()),
            "rows": rows,
        }
        os.makedirs(os.path.dirname(os.path.abspath(out_path)),
                    exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {os.path.abspath(out_path)}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="small synthetic case, no JSON (CI wiring check)")
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default: {DEFAULT_OUT})")
    args = ap.parse_args()
    if args.dry:
        main(DRY_CASES, out_path=args.out)       # no JSON unless asked
    else:
        main(CASES, args.out or DEFAULT_OUT)
