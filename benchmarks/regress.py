"""Bench-regression guard: machine-checked perf trajectory.

Compares a *fresh* benchmark run's headline numbers against the committed
``BENCH_*.json`` files (indexed by ``BENCH_manifest.json``) with
per-metric tolerance bands, and exits nonzero on regression — the repo's
first automated answer to "did this PR make the solver slower?".

Tolerance policy (DESIGN.md §14): every check names a direction.
``higher``-is-better metrics (ips, speedups, ratios) must stay above
``committed * (1 - rel) - abs_slack``; ``lower``-is-better metrics
(latency, overhead %, resident bytes) must stay below
``committed * (1 + rel) + abs_slack``; ``match`` metrics (deterministic
byte counts) must agree within the band in both directions.  Bands are
deliberately wide for wall-clock metrics (CPU container noise) and tight
for deterministic ones; ``--tol-scale`` widens or narrows all of them.

Modes:

    PYTHONPATH=src python -m benchmarks.regress --dry
        No fresh runs: validate the manifest, the committed files, and
        every check's extraction path (committed-vs-committed must pass
        by construction) — the timing-insensitive CI lane.

    PYTHONPATH=src python -m benchmarks.regress [--bench obs,streaming]
        Re-run the named benches with the *same* cases the committed
        files were produced from, then compare.  Default set is the
        cheap pair; ``--bench all`` sweeps every bench with a runner.

Exit codes: 0 pass, 1 regression, 3 plumbing error (missing manifest /
file / metric).
"""
from __future__ import annotations

import argparse
import dataclasses
import fnmatch
import json
import os
import sys
import tempfile
from typing import Callable, Optional

from . import manifest as manifest_mod

ROOT = manifest_mod.ROOT


@dataclasses.dataclass(frozen=True)
class Check:
    bench: str
    metric: str                 # headline key, or fnmatch pattern
    direction: str = "higher"   # "higher" | "lower" | "match"
    rel: float = 0.35           # allowed relative degradation
    abs_slack: float = 0.0      # additive slack in metric units


# The tolerance table.  Two classes of wall-clock metric, very different
# noise profiles on the 2-core container: *within-run ratios* (overhead
# %, streaming/drain, batched/solo, sharded speedups) divide two
# measurements from the same run and get moderate bands — they are the
# real guard; *cross-run absolutes* (ips, latency) swing 2-3x with
# machine load, so their bands are order-of-magnitude sanity floors
# only.  Deterministic byte counts must match.
CHECKS = [
    # first-request cold start (BENCH_coldstart.json): the within-run
    # ratios are the real guard — a warmed first request must stay far
    # below a cold one (the warmup ladder's whole claim) and within its
    # committed band; absolute latencies are cross-run wall clock
    Check("coldstart", "warmed_over_cold", "lower", rel=1.0,
          abs_slack=0.15),
    Check("coldstart", "persist_over_cold", "lower", rel=1.0,
          abs_slack=0.25),
    Check("coldstart", "warmed_p99_s", "lower", rel=1.5, abs_slack=0.5),
    Check("coldstart", "cold_p99_s", "lower", rel=1.5, abs_slack=2.0),
    # telemetry overhead (BENCH_obs.json)
    Check("obs", "overhead_pct", "lower", rel=0.0, abs_slack=6.0),
    Check("obs", "serving_overhead_pct", "lower", rel=0.0, abs_slack=6.0),
    Check("obs", "full_vs_off_ips", "higher", rel=0.10),
    Check("obs", "serving_vs_off_ips", "higher", rel=0.10),
    Check("obs", "off_ips", "higher", rel=0.7),
    Check("obs", "full_lat_mean_s", "lower", rel=1.5, abs_slack=0.25),
    # streaming vs drain (BENCH_streaming.json)
    Check("streaming", "ips_ratio", "higher", rel=0.35),
    Check("streaming", "lat_mean_ratio", "lower", rel=0.6, abs_slack=0.25),
    Check("streaming", "streaming_ips", "higher", rel=0.7),
    Check("streaming", "drain_ips", "higher", rel=0.7),
    # batched-vs-solo engine (BENCH_solver.json)
    Check("solver", "b*_speedup", "higher", rel=0.35),
    Check("solver", "b*_batch_ips", "higher", rel=0.7),
    # placement layer (BENCH_sharded.json)
    Check("sharded", "speedup_8v1", "higher", rel=0.35),
    Check("sharded", "d8_ips", "higher", rel=0.7),
    # sparse/paged representation (BENCH_sparse.json): residency is
    # deterministic, throughput is wall-clock
    Check("sparse", "*_resident_bytes", "match", rel=0.02),
    Check("sparse", "*_dense_over_sparse", "match", rel=0.05),
    Check("sparse", "*_iters_per_s", "higher", rel=0.7),
    # quantised resident tau (DESIGN.md §15): byte counts and compression
    # ratios are deterministic — int8 must hold ~3.9x, bf16 exactly 2x
    Check("sparse", "*_tau_bytes", "match", rel=0.0),
    Check("sparse", "*_tau_fp32_over", "match", rel=0.02),
    Check("streaming", "tau_ratio_bf16", "match", rel=0.0),
    Check("streaming", "tau_ratio_int8", "match", rel=0.02),
    Check("streaming", "slot_bytes_*", "match", rel=0.0),
    # construction hot path (BENCH_construction.json)
    Check("construction", "nn_lazy_speedup", "higher", rel=0.35),
    # solution quality (BENCH_quality.json): deterministic seeds, but a
    # gap near 0 needs additive slack, not relative
    Check("quality", "*_gap_pct", "lower", rel=0.05, abs_slack=2.0),
    # quantised quality gate (DESIGN.md §15): signed drift vs fp32 must
    # stay within the same absolute band it was committed at
    Check("quality", "*_vs_fp32_pct", "match", rel=0.0, abs_slack=1.0),
]

DEFAULT_BENCHES = ("obs", "streaming")


# ------------------------------------------------------- fresh bench runs
def _fresh_coldstart(out: str) -> None:
    from . import coldstart
    coldstart.main(coldstart.CASE, out_path=out)


def _fresh_obs(out: str) -> None:
    from . import obs_overhead
    obs_overhead.main(obs_overhead.CASE, out_path=out)


def _fresh_streaming(out: str) -> None:
    from . import streaming_throughput
    streaming_throughput.main(streaming_throughput.CASE, out_path=out)


def _fresh_solver(out: str) -> None:
    from . import solver_throughput
    solver_throughput.main(solver_throughput.CASES, out_path=out)


def _fresh_sharded(out: str) -> None:
    from . import sharded_throughput
    sharded_throughput.main(sharded_throughput.CASE, out_path=out)


def _fresh_sparse(out: str) -> None:
    from . import sparse_scale
    sparse_scale.main(sparse_scale.CASES, out_path=out)


def _fresh_construction(out: str) -> None:
    from . import construction_profile
    construction_profile.main(construction_profile.FULL_SIZES, out=out)


def _fresh_quality(out: str) -> None:
    from . import quality
    quality.main(out_path=out)


RUNNERS: dict[str, Callable[[str], None]] = {
    "coldstart": _fresh_coldstart,
    "obs": _fresh_obs,
    "streaming": _fresh_streaming,
    "solver": _fresh_solver,
    "sharded": _fresh_sharded,
    "sparse": _fresh_sparse,
    "construction": _fresh_construction,
    "quality": _fresh_quality,
}


# ------------------------------------------------------------- comparison
def _flatten(headline: dict) -> dict[str, float]:
    """Numeric leaves of a headline dict, nested dicts flattened with
    dotted keys (``nn_lazy_speedup.256``)."""
    out: dict[str, float] = {}
    for k, v in headline.items():
        if isinstance(v, dict):
            for kk, vv in _flatten(v).items():
                out[f"{k}.{kk}"] = vv
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            out[k] = float(v)
    return out


def _match_keys(flat: dict, pattern: str) -> list[str]:
    if pattern in flat:
        return [pattern]
    return sorted(k for k in flat
                  if fnmatch.fnmatch(k, pattern)
                  or fnmatch.fnmatch(k.split(".", 1)[0], pattern))


def evaluate(check: Check, committed: float, fresh: float,
             tol_scale: float = 1.0) -> tuple[bool, str]:
    rel = check.rel * tol_scale
    slack = check.abs_slack * tol_scale
    if check.direction == "higher":
        bound = committed * (1.0 - rel) - slack
        ok = fresh >= bound
        desc = f">= {bound:.4g}"
    elif check.direction == "lower":
        bound = committed * (1.0 + rel) + slack
        ok = fresh <= bound
        desc = f"<= {bound:.4g}"
    elif check.direction == "match":
        band = rel * max(abs(committed), 1e-12) + slack
        ok = abs(fresh - committed) <= band
        desc = f"within +-{band:.4g} of {committed:.4g}"
    else:
        raise ValueError(f"unknown direction {check.direction!r}")
    return ok, desc


def _load_payload(root: str, fname: str) -> dict:
    with open(os.path.join(root, fname)) as f:
        return json.load(f)


def run_checks(benches: list[str], dry: bool, tol_scale: float,
               root: str = ROOT) -> int:
    """Run the guard; returns the process exit code."""
    man_path = os.path.join(root, manifest_mod.MANIFEST_NAME)
    if not os.path.exists(man_path):
        print(f"regress: no {manifest_mod.MANIFEST_NAME} at {root} — run "
              f"`python -m benchmarks.manifest` first", file=sys.stderr)
        return 3
    man = manifest_mod.load_manifest(root)
    if man.get("schema") != manifest_mod.SCHEMA:
        print(f"regress: unexpected manifest schema {man.get('schema')!r}",
              file=sys.stderr)
        return 3

    failures = 0
    plumbing = 0
    checked = 0
    for bench in benches:
        entry = man["benches"].get(bench)
        if not entry or not entry.get("present"):
            print(f"regress: [{bench}] no committed BENCH file — skipped")
            continue
        committed_payload = _load_payload(root, entry["file"])
        committed = _flatten(
            manifest_mod.headline(bench, committed_payload))
        # sanity: the manifest's stored headline must agree with a fresh
        # extraction of the committed file (catches drifted manifests)
        stored = _flatten(entry.get("headline", {}))
        for k, v in stored.items():
            if k in committed and abs(committed[k] - v) > 1e-9:
                print(f"regress: [{bench}] manifest headline {k} "
                      f"({v}) != committed file ({committed[k]}) — "
                      f"regenerate the manifest", file=sys.stderr)
                plumbing += 1

        if dry:
            fresh = dict(committed)
        else:
            runner = RUNNERS.get(bench)
            if runner is None:
                print(f"regress: [{bench}] no fresh runner — skipped")
                continue
            out = os.path.join(tempfile.mkdtemp(prefix="regress_"),
                               f"{bench}.json")
            print(f"regress: [{bench}] fresh run -> {out}")
            runner(out)
            fresh = _flatten(
                manifest_mod.headline(bench, _load_payload(root=os.path.
                                      dirname(out), fname=os.path.
                                      basename(out))))

        bench_checks = [c for c in CHECKS if c.bench == bench]
        for check in bench_checks:
            keys = _match_keys(committed, check.metric)
            if not keys:
                print(f"regress: [{bench}] metric {check.metric!r} not in "
                      f"committed headline — check table out of date",
                      file=sys.stderr)
                plumbing += 1
                continue
            for key in keys:
                if key not in fresh:
                    print(f"regress: [{bench}] {key}: missing from fresh "
                          f"run", file=sys.stderr)
                    plumbing += 1
                    continue
                ok, band = evaluate(check, committed[key], fresh[key],
                                    tol_scale)
                checked += 1
                status = "ok" if ok else "REGRESSION"
                print(f"regress: [{bench}] {key}: committed="
                      f"{committed[key]:.4g} fresh={fresh[key]:.4g} "
                      f"({check.direction}, {band}) {status}")
                if not ok:
                    failures += 1

    print(f"regress: {checked} checks, {failures} regressions, "
          f"{plumbing} plumbing errors"
          + (" (dry)" if dry else ""))
    if plumbing:
        return 3
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry", action="store_true",
                    help="no fresh runs: validate manifest + tolerance "
                         "plumbing against the committed files only")
    ap.add_argument("--bench", default=None,
                    help="comma-separated benches to run fresh (default "
                         f"{','.join(DEFAULT_BENCHES)}; 'all' = every "
                         "bench with a runner); --dry checks all benches")
    ap.add_argument("--tol-scale", type=float, default=1.0,
                    help="multiply every tolerance band (2.0 = twice as "
                         "forgiving)")
    args = ap.parse_args()
    if args.dry:
        benches = (args.bench.split(",") if args.bench
                   else sorted(manifest_mod.BENCH_FILES))
    elif args.bench == "all":
        benches = sorted(RUNNERS)
    elif args.bench:
        benches = args.bench.split(",")
    else:
        benches = list(DEFAULT_BENCHES)
    sys.exit(run_checks(benches, args.dry, args.tol_scale))


if __name__ == "__main__":
    main()
