"""Local-search timing table (DESIGN.md §7): per-round cost of the batched
NN-restricted 2-opt / Or-opt passes, JAX vs the Pallas two_opt route, and
the quality they buy per round on a known-optimum instance.

    PYTHONPATH=src python benchmarks/local_search.py [--full]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aco, localsearch, strategies, tsp

try:
    from .timing import time_fn
except ImportError:  # run directly: python benchmarks/local_search.py
    from timing import time_fn

# (n, m): instance size x batch of tours improved at once
SIZES = ((100, 32), (280, 64))
FULL_SIZES = ((100, 32), (280, 64), (442, 128), (1002, 256))
ROUNDS = 8


def _tours(n: int, m: int):
    inst = tsp.circle_instance(n, seed=n)
    prob = aco.make_problem(inst, min(30, n - 1))
    ci = strategies.choice_matrix(jnp.ones((n, n)), prob.eta, 1.0, 2.0)
    res = strategies.construct_tours(jax.random.PRNGKey(n), prob.dist, ci, m)
    return inst, prob, res


def rows(sizes=SIZES):
    out = []
    for n, m in sizes:
        inst, prob, res = _tours(n, m)
        r = {"n": n, "m": m, "k": int(prob.nn.shape[1]), "rounds": ROUNDS,
             "start_gap_pct":
                 100 * (float(np.asarray(res.lengths).mean())
                        / inst.known_optimum - 1)}
        for name, cfg in (
            ("2opt", localsearch.LocalSearchConfig("2opt", rounds=ROUNDS)),
            ("2opt_first", localsearch.LocalSearchConfig(
                "2opt", rounds=ROUNDS, improvement="first")),
            ("oropt", localsearch.LocalSearchConfig("oropt", rounds=ROUNDS)),
            ("2opt_oropt", localsearch.LocalSearchConfig(
                "2opt_oropt", rounds=ROUNDS)),
            ("2opt_pallas", localsearch.LocalSearchConfig(
                "2opt", rounds=ROUNDS, use_pallas=True)),
        ):
            fn = jax.jit(lambda t, c=cfg: localsearch.improve_with_lengths(
                prob.dist, prob.nn, t, c))
            r[f"{name}_ms"] = round(time_fn(fn, res.tours, warmup=1,
                                            iters=3), 2)
            _, lens = fn(res.tours)
            r[f"{name}_gap_pct"] = round(
                100 * (float(np.asarray(lens).mean())
                       / inst.known_optimum - 1), 2)
        out.append(r)
    return out


def main(sizes=SIZES):
    print(f"local search: {ROUNDS} rounds over (m) tours, ms total "
          f"+ mean gap-to-optimum after")
    hdr = None
    for r in rows(sizes):
        if hdr is None:
            hdr = list(r.keys())
            print(",".join(hdr))
        print(",".join(str(r[k]) for k in hdr))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    main(FULL_SIZES if ap.parse_args().full else SIZES)
