"""Instance-batched solver throughput: batched vs solve-one-at-a-time.

For each (bucket, batch, iterations) case, a workload of ``batch`` mixed-size
instances (all landing in one bucket) is solved two ways with the same
engine, seeds and budgets:

- ``solo``   a Python loop over B single-instance (vmap B=1) engine calls —
             the baseline a naive deployment would run;
- ``batched``one vmapped call advancing all B colonies together.

Both paths are compile-warmed before timing, so the table isolates steady-
state throughput (instances/sec); the batched row's speedup is the gain of
filling the device with whole colonies (PAPERS.md: a single mid-size
instance cannot saturate a modern accelerator).

Emits ``BENCH_solver.json`` at the repo root (path resolved against this
file, so it works from any cwd).

    PYTHONPATH=src python benchmarks/solver_throughput.py [--smoke] [--out P]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax.numpy as jnp

from repro.core import aco, tsp
from repro.solver import batch as batch_mod
from repro.solver import engine

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_solver.json")

# (bucket, batch, iterations). Buckets >= 64: below that the whole colony
# step is so small on CPU that per-call overhead, not compute, is measured.
CASES = ((64, 4, 20), (64, 8, 20), (128, 8, 15))
SMOKE_CASES = ((64, 4, 8),)
REPS = 3   # best-of-N timing to damp scheduler noise


def _workload(bucket: int, batch: int):
    """Mixed sizes in (bucket/2, bucket] so every instance pads to bucket."""
    lo = bucket // 2 + 1
    sizes = [lo + (i * (bucket - lo)) // max(batch - 1, 1)
             for i in range(batch)]
    return [tsp.random_instance(n, seed=100 + i)
            for i, n in enumerate(sizes)]


def _run_solo(instances, cfg, iters, bucket):
    for i, inst in enumerate(instances):
        st, _ = engine.solve_instances([inst], cfg, iterations=[iters],
                                       seeds=[i], n_pad=bucket)
        st.best_len.block_until_ready()


def _run_batched(instances, cfg, iters, bucket):
    st, _ = engine.solve_instances(instances, cfg,
                                   iterations=[iters] * len(instances),
                                   seeds=list(range(len(instances))),
                                   n_pad=bucket)
    st.best_len.block_until_ready()


def rows(cases=CASES):
    out = []
    for bucket, batch, iters in cases:
        instances = _workload(bucket, batch)
        cfg = aco.ACOConfig(iterations=iters)
        # warm both compiled programs (B=1 and B=batch) out of the timing
        _run_solo(instances, cfg, iters, bucket)
        _run_batched(instances, cfg, iters, bucket)

        solo_s = batch_s = float("inf")
        for _ in range(REPS):
            t0 = time.perf_counter()
            _run_solo(instances, cfg, iters, bucket)
            solo_s = min(solo_s, time.perf_counter() - t0)

            t0 = time.perf_counter()
            _run_batched(instances, cfg, iters, bucket)
            batch_s = min(batch_s, time.perf_counter() - t0)

        out.append({
            "bucket": bucket, "batch": batch, "iters": iters,
            "solo_s": round(solo_s, 4), "batch_s": round(batch_s, 4),
            "solo_ips": round(batch / solo_s, 3),
            "batch_ips": round(batch / batch_s, 3),
            "speedup": round(solo_s / batch_s, 3),
        })
    return out


def main(cases=CASES, out_path: str | None = None):
    out_path = out_path or DEFAULT_OUT
    print("solver throughput (instances/sec, batched vs one-at-a-time)")
    results = rows(cases)
    hdr = list(results[0])
    print(",".join(hdr))
    for r in results:
        print(",".join(str(r[k]) for k in hdr))
    payload = {
        "benchmark": "solver_throughput",
        "schema": 1,
        "unix_time": int(time.time()),
        "rows": results,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {os.path.abspath(out_path)}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="single small case")
    ap.add_argument("--out", default=None,
                    help=f"output JSON path (default: {DEFAULT_OUT})")
    args = ap.parse_args()
    main(SMOKE_CASES if args.smoke else CASES, args.out)
