"""Architecture registry: one module per assigned architecture.

``get(name)`` returns the full published ModelConfig; ``get_reduced(name)``
returns the same family scaled down for CPU smoke tests (few layers, narrow
width, few experts, tiny vocab). Shapes live in .shapes.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCHS = (
    "jamba_1_5_large_398b",
    "whisper_medium",
    "qwen2_vl_2b",
    "minitron_4b",
    "h2o_danube_3_4b",
    "deepseek_7b",
    "olmo_1b",
    "deepseek_v3_671b",
    "grok_1_314b",
    "mamba2_1_3b",
)

# dashes-to-underscores aliases used on CLIs
ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def canonical(name: str) -> str:
    name = name.replace("-", "_").replace(".", "_")
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    return name


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCHS}
