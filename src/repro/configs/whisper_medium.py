"""Whisper-medium (769M) [arXiv:2212.04356; unverified].

Encoder-decoder: 24 encoder + 24 decoder layers, d=1024, 16 heads (MHA),
GELU MLP (non-gated), LayerNorm, sinusoidal positions, no RoPE. The audio
conv frontend is a STUB per the task: input_specs() provides precomputed
frame embeddings (B, S_enc, d_model); `enc_in_proj` stands in for the conv
stack's output projection.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_head=64,
    d_ff=4096,
    vocab=51865,
    period=(LayerSpec(cross_attn=True),),
    enc_dec=True,
    n_enc_layers=24,
    mlp_kind="mlp",
    act="gelu",
    norm="layernorm",
    rope="none",
    pos_embed="sinusoidal",
    frontend="audio_stub",
)

REDUCED = ModelConfig(
    name="whisper-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_head=16,
    d_ff=128,
    vocab=256,
    period=(LayerSpec(cross_attn=True),),
    enc_dec=True,
    n_enc_layers=2,
    mlp_kind="mlp",
    act="gelu",
    norm="layernorm",
    rope="none",
    pos_embed="sinusoidal",
    frontend="audio_stub",
)
