"""Grok-1 (314B, 8 experts top-2) [hf:xai-org/grok-1; unverified].

64L x d6144, 48 heads (GQA kv=8, head dim 128), every layer MoE with 8
experts top-2 (expert d_ff 32768), GeGLU, 30.0 output logit soft-cap,
vocab 131072.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_head=128,
    d_ff=32768,
    vocab=131072,
    period=(LayerSpec(moe=True),),
    n_experts=8,
    top_k=2,
    d_ff_expert=32768,
    mlp_kind="swiglu",
    act="gelu",             # GeGLU
    norm="rmsnorm",
    rope="rope",
    logit_softcap=30.0,
)

REDUCED = ModelConfig(
    name="grok-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=(LayerSpec(moe=True),),
    n_experts=4,
    top_k=2,
    d_ff_expert=128,
    mlp_kind="swiglu",
    act="gelu",
    logit_softcap=30.0,
)
