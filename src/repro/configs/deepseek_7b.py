"""DeepSeek-LLM-7B [arXiv:2401.02954; hf].

Llama-architecture dense decoder: 30L x d4096, full MHA (kv=32), swiglu,
vocab 102400.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    d_head=128,
    d_ff=11008,
    vocab=102400,
    period=(LayerSpec(),),
    mlp_kind="swiglu",
    act="silu",
    norm="rmsnorm",
    rope="rope",
    rope_theta=10000.0,
)

REDUCED = ModelConfig(
    name="deepseek7b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_head=16,
    d_ff=160,
    vocab=512,
    period=(LayerSpec(),),
    mlp_kind="swiglu",
)
