"""OLMo-1B [arXiv:2402.00838; hf].

Dense decoder with **non-parametric LayerNorm** (no scale/bias — the OLMo
signature), full MHA, swiglu, tied embeddings, vocab 50304.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_head=128,
    d_ff=8192,
    vocab=50304,
    period=(LayerSpec(),),
    mlp_kind="swiglu",
    act="silu",
    norm="nonparam_ln",
    rope="rope",
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="olmo-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_head=16,
    d_ff=128,
    vocab=256,
    period=(LayerSpec(),),
    norm="nonparam_ln",
    tie_embeddings=True,
)
