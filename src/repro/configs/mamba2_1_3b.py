"""Mamba2-1.3B [arXiv:2405.21060; unverified].

Pure SSM (attention-free, no MLP blocks): 48 SSD layers, d=2048 (d_inner
4096, 64 heads x head_dim 64, state 128), vocab 50280, tied embeddings.
The d_ff=0 assignment means blocks are mamba-only — the model config
drops the MLP sublayer entirely.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=1,               # attention-free; unused
    n_kv=1,
    d_head=1,
    d_ff=0,                  # no MLP sublayer (pure mamba stack)
    vocab=50280,
    period=(LayerSpec(kind="mamba"),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    norm="rmsnorm",
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="mamba2-reduced",
    n_layers=3,
    d_model=64,
    n_heads=1,
    n_kv=1,
    d_head=1,
    d_ff=0,
    vocab=256,
    period=(LayerSpec(kind="mamba"),),
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_chunk=8,
    tie_embeddings=True,
)
