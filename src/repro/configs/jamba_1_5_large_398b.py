"""Jamba-1.5-Large (398B total / ~94B active) [arXiv:2403.19887 / 2408.12570; hf].

Hybrid Mamba+attention 1:7 interleave with MoE every other layer:
period of 8 = [attn, mamba x7], MoE on odd positions (4 MoE layers per
period, 16 experts top-2). 72 layers = 9 periods.

Adaptation note (DESIGN.md §6): Jamba ships Mamba-1 selective-scan blocks;
we implement the SSD (Mamba-2) formulation — same state-space interface,
MXU-friendlier chunked algorithm.
"""
from repro.models.config import LayerSpec, ModelConfig

_PERIOD = tuple(
    LayerSpec(kind=("attn" if i == 0 else "mamba"), moe=(i % 2 == 1))
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_head=128,
    d_ff=24576,
    vocab=65536,
    period=_PERIOD,
    n_experts=16,
    top_k=2,
    d_ff_expert=24576,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    rope="rope",           # attn layers use RoPE
    mlp_kind="swiglu",
    act="silu",
    norm="rmsnorm",
)

REDUCED = ModelConfig(
    name="jamba-reduced",
    n_layers=8,
    d_model=128,
    n_heads=4,
    n_kv=2,
    d_head=32,
    d_ff=256,
    vocab=512,
    period=tuple(
        LayerSpec(kind=("attn" if i == 0 else "mamba"), moe=(i % 2 == 1))
        for i in range(4)
    ),
    n_experts=4,
    top_k=2,
    d_ff_expert=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_chunk=8,
)
