"""Qwen2-VL-2B [arXiv:2409.12191; hf].

Dense decoder with M-RoPE (multimodal rotary: t/h/w frequency sections of
the 64 half-dims split 16/24/24). The vision ViT frontend is a STUB:
input_specs() provides token ids plus 3-channel position ids from the
dynamic-resolution patchifier. Tied embeddings (vocab 151936 dominates the
2B budget).
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    d_head=128,
    d_ff=8960,
    vocab=151936,
    period=(LayerSpec(),),
    rope="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    mlp_kind="swiglu",
    act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
    frontend="vision_stub",
)

REDUCED = ModelConfig(
    name="qwen2-vl-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    period=(LayerSpec(),),
    rope="mrope",
    mrope_sections=(2, 3, 3),
    mlp_kind="swiglu",
    tie_embeddings=True,
    frontend="vision_stub",
)
