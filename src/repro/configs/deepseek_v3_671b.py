"""DeepSeek-V3 (671B total / 37B active) [arXiv:2412.19437; hf].

61 layers: 3 dense prefix layers (d_ff 18432) + 58 MoE layers with 1 shared
+ 256 routed experts (top-8, expert d_ff 2048). Multi-head Latent Attention:
q LoRA rank 1536, kv LoRA rank 512, qk nope/rope 128/64, v head 128 — the KV
cache stores only 512+64 values per token. Depth-1 multi-token-prediction
auxiliary head enabled for training (matches the release; serving cells do
not lower it).
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv=128,
    d_head=128,
    d_ff=2048,              # routed-expert FFN width (assigned config)
    vocab=129280,
    prefix=(LayerSpec(),) * 3,
    period=(LayerSpec(moe=True),),
    d_ff_dense=18432,
    d_ff_expert=2048,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    mlp_kind="swiglu",
    act="silu",
    norm="rmsnorm",
    rope="rope",
    rope_theta=10000.0,
    mtp_depth=1,
)

REDUCED = ModelConfig(
    name="dsv3-reduced",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_head=16,
    d_ff=64,
    vocab=512,
    prefix=(LayerSpec(),),
    period=(LayerSpec(moe=True),),
    d_ff_dense=128,
    d_ff_expert=64,
    n_experts=8,
    top_k=2,
    n_shared_experts=1,
    attn_kind="mla",
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    mtp_depth=1,
)
