"""H2O-Danube-3-4B [arXiv:2401.16818 family; unverified].

Llama/Mistral mix: dense decoder with sliding-window attention (Mistral
window 4096), GQA kv=8, swiglu, 32000 vocab. SWA makes it eligible for the
long_500k decode cell with an O(window) ring-buffer KV cache.
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv=8,
    d_head=120,
    d_ff=10240,
    vocab=32000,
    period=(LayerSpec(),),
    window=4096,
    mlp_kind="swiglu",
    act="silu",
    norm="rmsnorm",
    rope="rope",
    rope_theta=10000.0,
)

REDUCED = ModelConfig(
    name="danube-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_head=16,
    d_ff=128,
    vocab=256,
    period=(LayerSpec(),),
    window=16,
    mlp_kind="swiglu",
)
