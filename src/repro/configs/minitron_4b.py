"""Minitron-4B (pruned Nemotron-4) [arXiv:2407.14679; hf].

Dense decoder, 32L x d3072, 24 heads (GQA kv=8, head dim 128), squared-ReLU
non-gated MLP (Nemotron family), huge 256000 vocab (tied per the release).
"""
from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv=8,
    d_head=128,
    d_ff=9216,
    vocab=256000,
    period=(LayerSpec(),),
    mlp_kind="mlp",
    act="relu2",
    norm="layernorm",
    rope="rope",
    rope_theta=10000.0,
    tie_embeddings=False,   # untied: 3.40B blocks + 0.79B x2 embed = 4.19B
)

REDUCED = ModelConfig(
    name="minitron-reduced",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv=2,
    d_head=16,
    d_ff=192,
    vocab=1024,
    period=(LayerSpec(),),
    mlp_kind="mlp",
    act="relu2",
    norm="layernorm",
    tie_embeddings=True,
)
