"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

CPU-scale usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2_vl_2b --reduced \
        --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import steps as st
from repro.launch.mesh import make_mesh_for
from repro.models import model


def serve(arch: str, batch: int, prompt_len: int, gen: int,
          reduced: bool = True, seed: int = 0) -> dict:
    cfg = configs.get_reduced(arch) if reduced else configs.get(arch)
    params = model.init_params(jax.random.PRNGKey(seed), cfg)
    max_len = prompt_len + gen + 1
    key = jax.random.PRNGKey(seed + 1)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab,
                                 dtype=jnp.int32)
    enc = None
    if cfg.enc_dec:
        enc = jax.random.normal(jax.random.fold_in(key, 1),
                                (batch, 64, cfg.d_model), jnp.float32)

    t0 = time.time()
    logits, caches, _ = model.prefill(params, prompts, cfg, max_len,
                                      enc_frames=enc)
    t_prefill = time.time() - t0

    serve_step = jax.jit(st.make_serve_step(cfg))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(gen - 1):
        tok, caches = serve_step(params, tok, caches)
        out_tokens.append(np.asarray(tok))
    t_decode = time.time() - t0
    gen_tokens = np.concatenate(out_tokens, axis=1)
    return {
        "prefill_s": t_prefill,
        "decode_s_per_token": t_decode / max(gen - 1, 1),
        "tokens": gen_tokens.tolist(),
        "throughput_tok_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()
    out = serve(args.arch, args.batch, args.prompt_len, args.gen,
                args.reduced)
    print(json.dumps({k: v for k, v in out.items() if k != "tokens"},
                     indent=2))


if __name__ == "__main__":
    main()
