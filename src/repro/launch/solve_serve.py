"""Batched TSP solver serving driver (mirrors launch/serve.py for the LM).

Two modes:

- default: generate a mixed workload, submit everything to the
  drain-the-queue SolverService, run the bucket scheduler, print JSON stats;
- ``--stream``: replay a Poisson arrival trace through the
  continuous-batching StreamingSolverService (DESIGN.md §9) — requests are
  admitted into resident slots mid-run as they arrive.

``--shard`` places the solver over a 1-D device mesh (DESIGN.md §11):
batch jobs shard their instance axis across the devices; streaming mode
runs one resident pool per device.  ``--devices`` bounds the mesh (default
all local devices).

``--sparse`` swaps the dense (n, n) pipeline for the candidate-list
O(n*k) paged representation (DESIGN.md §12) in the drain-the-queue mode;
sparse x streaming / sharding / local-search combinations exit 2 with the
route checker's one-line reason.

Telemetry (repro.obs, DESIGN.md §13): ``--metrics`` turns on the in-jit
convergence metrics (bitwise-neutral; each result gains a ``metrics``
row), ``--metrics-out``/``--trace-out``/``--events-out`` export the
registry snapshot, the Perfetto-loadable Chrome trace, and the JSON-lines
slot-lifecycle event log; ``--stats-every`` emits periodic stats_snapshot
events during a ``--stream`` replay and ``--jax-profile-dir`` wraps the
run in a jax.profiler capture.

Serving observability plane (DESIGN.md §14): ``--metrics-port`` serves
``GET /metrics`` (Prometheus text), ``/healthz`` (pool liveness +
occupancy) and ``/snapshot`` (the ``repro.obs/v1`` JSON) from a
background thread for the whole run; ``--metrics-hold`` keeps it up
after the drain for external scrapers.  ``--tenant a,b`` cycles tenant
labels over the workload — per-tenant SLO attainment and latency
quantiles then appear in ``/metrics`` and in the report's
``stats.tenants``:

    PYTHONPATH=src python -m repro.launch.solve_serve --stream \\
        --num-instances 8 --iterations 10 \\
        --metrics-port 9100 --metrics-hold 30 --tenant demo,batch &
    curl -s localhost:9100/metrics | grep slo_attainment
    curl -s localhost:9100/healthz

``--tau-dtype bf16|int8`` (DESIGN.md §15) holds every resident pheromone
matrix in low precision — bf16 halves, int8 (with per-row scales)
quarters the per-slot tau bytes, so a streaming pool fits 2-4x the
resident slots in the same memory; compute stays fp32 (the Pallas
selection kernels dequantise tile-by-tile in their epilogue) and
solution quality stays within 1% absolute of fp32 (benchmarks/quality
``quant_rows``):

    PYTHONPATH=src python -m repro.launch.solve_serve --tau-dtype int8 \\
        --num-instances 8 --iterations 20 --variant mmas
    PYTHONPATH=src python -m repro.launch.solve_serve --stream \\
        --tau-dtype int8 --num-instances 8 --chunk 2 --iterations 10

AOT program cache (DESIGN.md §16): ``--warmup`` pre-compiles the bucket
ladder for the [min_n, max_n] range before traffic (``--warmup-async``
on a background thread; ``--bucket-ladder 16,32`` overrides the rungs),
``--cache-dir`` enables the persistent XLA compilation cache so a
restart pays a cache load instead of a compile, and ``--dry`` compiles
the ladder, prints the program/cache stats as JSON and exits (the CI
smoke).  ``--draw-mode counter --ants M`` makes the randomness
bucket-width invariant, which lets admission neighbour-route an
unwarmed bucket into the nearest larger warmed one bitwise-exactly:

    PYTHONPATH=src python -m repro.launch.solve_serve --warmup \\
        --cache-dir /tmp/xla-cache --num-instances 8 --iterations 20
    PYTHONPATH=src python -m repro.launch.solve_serve --stream --warmup \\
        --warmup-async --draw-mode counter --ants 32 --num-instances 8
    PYTHONPATH=src python -m repro.launch.solve_serve --warmup --dry \\
        --cache-dir /tmp/xla-cache

CPU-scale usage:
    PYTHONPATH=src python -m repro.launch.solve_serve \
        --num-instances 8 --min-n 12 --max-n 48 --iterations 20
    PYTHONPATH=src python -m repro.launch.solve_serve --sparse \
        --sparse-k 16 --num-instances 6 --iterations 10 --variant mmas
    PYTHONPATH=src python -m repro.launch.solve_serve --stream \
        --num-instances 8 --arrival-rate 4 --chunk 2 --iterations 10 \
        --metrics --metrics-out /tmp/m.json --trace-out /tmp/t.json \
        --events-out /tmp/e.jsonl
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.solve_serve --shard \
        --num-instances 8 --iterations 10
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from repro import obs
from repro.core import aco, tsp
from repro.kernels.ops import UnsupportedKernelRoute
from repro.launch.mesh import make_data_mesh
from repro.solver import (ProgramCache, SolverService,
                          StreamingSolverService, enable_persistent_cache,
                          make_poisson_trace, persistent_cache_stats,
                          replay_trace)


def make_workload(num: int, min_n: int, max_n: int, seed: int):
    """Alternating random/circle instances with sizes across the range
    (circle instances carry a known optimum, so the service reports gaps)."""
    rng = np.random.RandomState(seed)
    out = []
    for i in range(num):
        n = int(rng.randint(min_n, max_n + 1))
        if i % 2 == 0:
            out.append(tsp.circle_instance(n, seed=seed + i))
        else:
            out.append(tsp.random_instance(n, seed=seed + i))
    return out


def _round(obj, nd: int = 4):
    """Recursive float rounding: one rule for every level of the report
    (the old one-level dict comprehension left nested stats — bucket maps,
    histogram summaries, metrics rows — unrounded and inconsistent)."""
    if isinstance(obj, float):
        return round(obj, nd) if np.isfinite(obj) else obj
    if isinstance(obj, dict):
        return {k: _round(v, nd) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_round(v, nd) for v in obj]
    return obj


def _report(results, stats) -> None:
    gaps = [r.gap_pct for r in results if r.gap_pct is not None]
    rows = []
    for r in results:
        row = {"id": r.request_id, "name": r.name, "n": r.n,
               "bucket": r.bucket, "best_len": r.best_len,
               "iterations": r.iterations, "gap_pct": r.gap_pct,
               "latency_s": r.latency_s}
        if r.trace_id:
            row["trace_id"] = r.trace_id
        if r.tenant is not None:
            row["tenant"] = r.tenant
        if r.expired:
            row["expired"] = True
        if r.metrics is not None:
            row["metrics"] = r.metrics
        rows.append(row)
    # flush: under --metrics-hold the process may be killed right after
    # the hold starts, and the redirected report must already be on disk
    print(json.dumps(_round({
        "schema": "repro.solve_serve/v1",
        "results": rows,
        "mean_gap_pct": float(np.mean(gaps)) if gaps else None,
        "stats": stats,
    }), indent=2), flush=True)


def _start_metrics_server(args, tel, svc):
    """Bind the exposition endpoint (obs.serving.MetricsServer) over the
    run's Telemetry with the service's live health view; announces the
    bound port on stderr (stdout stays pure JSON for the report)."""
    if args.metrics_port is None:
        return None
    server = obs.MetricsServer(tel, health_fn=svc.health,
                               snapshot_extra_fn=lambda: {"stats": svc.stats},
                               port=args.metrics_port)
    print(f"solve_serve: metrics endpoint on "
          f"http://127.0.0.1:{server.port} "
          f"(/metrics /healthz /snapshot)", file=sys.stderr)
    return server


def _hold_endpoint(args, server) -> None:
    """--metrics-hold: keep serving after the drain so an external
    scraper (the CI observability lane) can read the final state."""
    if server is not None and args.metrics_hold > 0:
        time.sleep(args.metrics_hold)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-instances", type=int, default=8)
    ap.add_argument("--min-n", type=int, default=12)
    ap.add_argument("--max-n", type=int, default=48)
    ap.add_argument("--iterations", type=int, default=20)
    ap.add_argument("--variant", default="as", choices=["as", "mmas", "acs"])
    ap.add_argument("--selection", default="iroulette")
    ap.add_argument("--local-search", default="none")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--min-bucket", type=int, default=16)
    ap.add_argument("--patience", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--use-pallas", action="store_true",
                    help="route choice/construction/deposit through the "
                         "mask-aware Pallas kernels (interpret mode on CPU)")
    # quantised resident pheromone (core/quant.py, DESIGN.md §15)
    ap.add_argument("--tau-dtype", default="fp32",
                    choices=["fp32", "bf16", "int8"],
                    help="resident pheromone precision: bf16 halves / int8 "
                         "quarters the per-slot tau bytes (per-row scales, "
                         "stochastic quantise-on-store); compute and the "
                         "kernel dequant epilogues stay fp32")
    ap.add_argument("--tau-round", default="stochastic",
                    choices=["stochastic", "nearest"],
                    help="--tau-dtype bf16/int8: quantise-on-store rounding")
    # sparse/paged representation (DESIGN.md §12)
    ap.add_argument("--sparse", action="store_true",
                    help="candidate-list-restricted O(n*k) representation: "
                         "no resident (n, n) tensor; incompatible with "
                         "--stream/--shard and local search")
    ap.add_argument("--sparse-k", type=int, default=32,
                    help="--sparse: candidate-list width per city")
    ap.add_argument("--sparse-overflow", type=int, default=4,
                    help="--sparse: per-city off-list adoption slots "
                         "(0 disables adoption)")
    # multi-device fabric (placement layer, DESIGN.md §11)
    ap.add_argument("--shard", action="store_true",
                    help="shard the solver over a 1-D device mesh: batch "
                         "jobs split their instance axis across devices; "
                         "--stream runs one resident pool per device")
    ap.add_argument("--devices", type=int, default=None,
                    help="--shard: mesh size (default: all local devices)")
    # streaming mode (continuous batching, DESIGN.md §9)
    ap.add_argument("--stream", action="store_true",
                    help="replay a Poisson arrival trace through the "
                         "continuous-batching streaming service")
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="--stream: Poisson arrivals per second")
    ap.add_argument("--chunk", type=int, default=2,
                    help="--stream: iterations per scheduler tick")
    ap.add_argument("--max-waiting", type=int, default=None,
                    help="--stream: admission backpressure bound")
    ap.add_argument("--per-instance-hyper", action="store_true",
                    help="--stream: per-slot alpha/beta/rho/q operands so "
                         "one bucket mixes tuning profiles (incompatible "
                         "with --use-pallas)")
    # telemetry fabric (repro.obs, DESIGN.md §13)
    ap.add_argument("--metrics", action="store_true",
                    help="carry in-jit convergence metrics next to every "
                         "colony (bitwise-neutral): each result gains a "
                         "metrics row")
    ap.add_argument("--metrics-out", default=None,
                    help="write the repro.obs/v1 registry snapshot JSON "
                         "here at exit")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome-trace (Perfetto-loadable) "
                         "timeline JSON here at exit")
    ap.add_argument("--events-out", default=None,
                    help="mirror the JSON-lines slot-lifecycle event log "
                         "to this file as records arrive")
    ap.add_argument("--stats-every", type=float, default=0.0,
                    help="--stream: emit a stats_snapshot event every this "
                         "many seconds during the replay")
    ap.add_argument("--jax-profile-dir", default=None,
                    help="capture a jax.profiler trace (XPlane/TensorBoard)"
                         " of the whole run into this directory")
    # serving observability plane (repro.obs.serving, DESIGN.md §14)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve GET /metrics (Prometheus text), /healthz "
                         "(pool liveness + occupancy JSON) and /snapshot "
                         "(repro.obs/v1 JSON) on this port for the whole "
                         "run (0 = ephemeral; the bound port is printed "
                         "to stderr)")
    ap.add_argument("--metrics-hold", type=float, default=0.0,
                    help="keep the --metrics-port endpoint up this many "
                         "seconds after the workload drains (lets an "
                         "external scraper read the final state)")
    ap.add_argument("--tenant", default=None,
                    help="tenant label(s) for per-tenant SLO accounting: "
                         "a single label, or a comma-separated list "
                         "cycled across the workload (labels never touch "
                         "the solve)")
    # AOT program cache (solver/programs.py, DESIGN.md §16)
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile the service's program for every "
                         "bucket in [--min-n, --max-n] before admitting "
                         "traffic, so no request pays a serve-time "
                         "compile; warmed buckets also enable neighbour-"
                         "bucket admission routing when the config's "
                         "numerics are bucket-width invariant "
                         "(--draw-mode counter with --ants pinned)")
    ap.add_argument("--warmup-async", action="store_true",
                    help="--warmup on a background thread: traffic is "
                         "admitted immediately and falls back to the jit "
                         "path until each bucket's compile lands")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent XLA compilation cache directory: "
                         "compiled executables survive restarts, so the "
                         "second cold start pays a cache load, not a "
                         "compile")
    ap.add_argument("--bucket-ladder", default=None,
                    help="--warmup: explicit comma-separated bucket list "
                         "(default: batch.bucket_ladder over "
                         "[--min-n, --max-n])")
    ap.add_argument("--dry", action="store_true",
                    help="--warmup: compile the ladder, report program/"
                         "cache stats as JSON and exit without running "
                         "a workload (CI smoke)")
    ap.add_argument("--draw-mode", default="packed",
                    choices=["packed", "counter"],
                    help="per-(ant, city) randomness derivation: "
                         "'counter' makes draws invariant to the padded "
                         "bucket width — required for neighbour-bucket "
                         "routing (core/sampling.py)")
    ap.add_argument("--ants", type=int, default=None,
                    help="pin the ant count (default: m = n_pad); "
                         "required for neighbour-bucket routing")
    args = ap.parse_args()

    cfg = aco.ACOConfig(iterations=args.iterations, variant=args.variant,
                        selection=args.selection,
                        local_search=args.local_search, seed=args.seed,
                        m=args.ants, draw_mode=args.draw_mode,
                        use_pallas=args.use_pallas, sparse=args.sparse,
                        sparse_k=args.sparse_k,
                        sparse_overflow=args.sparse_overflow,
                        tau_dtype=args.tau_dtype, tau_round=args.tau_round,
                        metrics=args.metrics)
    mesh = make_data_mesh(args.devices) if args.shard else None
    tel = obs.Telemetry(events_path=args.events_out,
                        jax_profile_dir=args.jax_profile_dir)
    tenants = (args.tenant.split(",") if args.tenant else None)
    server = None

    if args.dry and not args.warmup:
        ap.error("--dry requires --warmup")
    if args.cache_dir:
        enable_persistent_cache(args.cache_dir)
    programs = ProgramCache(telemetry=tel) if args.warmup else None
    ladder = ([int(x) for x in args.bucket_ladder.split(",")]
              if args.bucket_ladder else None)

    def _warm(svc):
        """Run the warmup ladder; with --dry, print the report and tell
        the caller to skip the workload."""
        if programs is None:
            return False
        t0 = time.perf_counter()
        summary = svc.warm_programs(args.min_n, args.max_n, ladder=ladder,
                                    background=args.warmup_async
                                    and not args.dry)
        warm_s = time.perf_counter() - t0
        if not args.dry:
            print(f"solve_serve: warmup "
                  f"{'started (background)' if args.warmup_async else f'done in {warm_s:.2f}s'}",
                  file=sys.stderr)
            return False
        report = {
            "schema": "repro.solve_serve/v1",
            "dry": True,
            "warmup": summary,
            "stats": {"programs": programs.stats()},
        }
        if args.cache_dir:
            report["cache"] = persistent_cache_stats(args.cache_dir)
        print(json.dumps(_round(report), indent=2), flush=True)
        return True

    try:
        tel.profile_start()
        if args.stream:
            if args.checkpoint_dir:
                ap.error("--checkpoint-dir is not supported with --stream "
                         "(streaming checkpointing is not implemented)")
            svc = StreamingSolverService(
                cfg, max_batch=args.max_batch, min_bucket=args.min_bucket,
                chunk=args.chunk, patience=args.patience,
                max_waiting=args.max_waiting,
                per_instance_hyper=args.per_instance_hyper, mesh=mesh,
                telemetry=tel, snapshot_every=args.stats_every,
                programs=programs)
            server = _start_metrics_server(args, tel, svc)
            if _warm(svc):
                return
            trace = make_poisson_trace(args.num_instances, args.arrival_rate,
                                       args.min_n, args.max_n,
                                       seed=args.seed,
                                       iterations=args.iterations,
                                       tenants=tenants)
            results = replay_trace(svc, trace)
            _report(sorted(results, key=lambda r: r.request_id), svc.stats)
        else:
            if args.per_instance_hyper:
                ap.error("--per-instance-hyper requires --stream")
            svc = SolverService(cfg, max_batch=args.max_batch,
                                min_bucket=args.min_bucket,
                                patience=args.patience,
                                checkpoint_dir=args.checkpoint_dir,
                                mesh=mesh, telemetry=tel,
                                programs=programs)
            server = _start_metrics_server(args, tel, svc)
            if _warm(svc):
                return
            for i, inst in enumerate(make_workload(
                    args.num_instances, args.min_n, args.max_n, args.seed)):
                svc.submit(inst, tenant=(tenants[i % len(tenants)]
                                         if tenants else None))
            results = svc.run()
            _report(results, svc.stats)
        if args.metrics_out:
            tel.write_metrics(args.metrics_out, extra={"stats": svc.stats})
        if args.trace_out:
            tel.write_trace(args.trace_out)
        # hold last: the report and exports are already on disk, so the
        # external scraper can kill us whenever it has what it needs
        _hold_endpoint(args, server)
    except UnsupportedKernelRoute as e:
        # one actionable line instead of a traceback (DESIGN.md §10/§12:
        # the route checker's message already says which flag to drop)
        print(f"solve_serve: {e}", file=sys.stderr)
        sys.exit(2)
    finally:
        if server is not None:
            server.close()
        tel.close()


if __name__ == "__main__":
    main()
