"""End-to-end LM trainer: config -> mesh -> sharded train loop with
checkpoint/restart, resumable data pipeline, and optional gradient
compression.

CPU-scale usage (examples/train_lm.py drives this):
    PYTHONPATH=src python -m repro.launch.train --arch olmo_1b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Cluster usage is identical with --mesh-model/--mesh-pods on real devices;
restarts pick up the newest checkpoint (params, optimizer, data cursor).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import checkpoint as ck
from repro import configs
from repro.data import DataConfig, SyntheticLMData
from repro.launch import steps as st
from repro.launch.mesh import make_mesh_for
from repro.models import model, sharding as sh
from repro.optim import adamw


def train(arch: str, steps: int, batch: int, seq: int, reduced: bool = True,
          ckpt_dir: str | None = None, ckpt_every: int = 20,
          model_parallel: int = 1, compress: bool = False,
          seed: int = 0, log_every: int = 10, lr: float = 3e-4) -> dict:
    cfg = configs.get_reduced(arch) if reduced else configs.get(arch)
    mesh = make_mesh_for(model_parallel=model_parallel)
    opt_cfg = adamw.AdamWConfig(lr=lr, total_steps=max(steps, 2),
                                warmup_steps=max(steps // 20, 1))

    params = model.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = adamw.adamw_init(params)
    pspecs = sh.param_specs(params, cfg, mesh)
    psh = sh.to_shardings(pspecs, mesh)
    rep = NamedSharding(mesh, P())
    osh = adamw.AdamWState(mu=psh, nu=psh, step=rep)
    params = jax.device_put(params, psh)
    opt_state = jax.device_put(opt_state, osh)

    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch,
                      seed=seed)
    data = SyntheticLMData(dcfg)
    start_step = 0

    mgr = None
    if ckpt_dir:
        mgr = ck.CheckpointManager(ckpt_dir, keep=3)
        latest = mgr.latest_step()
        if latest is not None:
            tmpl = {"params": params, "opt": opt_state,
                    "data": {"step": 0, "seed": seed}}
            shd = {"params": psh, "opt": osh,
                   "data": {"step": rep, "seed": rep}}
            restored, start_step = mgr.restore(tmpl, shardings=shd)
            params, opt_state = restored["params"], restored["opt"]
            data = SyntheticLMData.restore(dcfg, jax.tree.map(
                int, restored["data"]))
            print(f"[train] resumed from step {start_step}", flush=True)

    dspec = sh.data_specs(cfg, mesh, batch)
    dsh = NamedSharding(mesh, dspec)
    step_fn = jax.jit(
        st.make_train_step(cfg, opt_cfg, remat=True, compress=compress),
        in_shardings=(psh, osh, dsh, dsh),
        out_shardings=(psh, osh, rep),
        donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    for i in range(start_step, steps):
        tokens, labels = next(data)
        params, opt_state, metrics = step_fn(
            params, opt_state,
            jax.device_put(jnp.asarray(tokens), dsh),
            jax.device_put(jnp.asarray(labels), dsh))
        if (i + 1) % log_every == 0 or i == steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            print(f"[train] step {i+1}/{steps} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i+1-start_step):.2f}s/step)",
                  flush=True)
        if mgr and (i + 1) % ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt_state,
                             "data": data.state()})
    if mgr:
        mgr.save(steps, {"params": params, "opt": opt_state,
                         "data": data.state()})
        mgr.wait()
    return {"final_loss": losses[-1] if losses else None, "losses": losses}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo_1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(args.arch, args.steps, args.batch, args.seq, args.reduced,
                args.ckpt_dir, args.ckpt_every, args.model_parallel,
                args.compress, args.seed)
    print(json.dumps({"final_loss": out["final_loss"]}))


if __name__ == "__main__":
    main()
