"""Beyond-baseline performance overrides per (arch, shape) cell.

Each entry is a dataclasses.replace() kwargs dict applied to the published
ModelConfig before lowering. These change LAYOUT/SCHEDULE only, never the
computed function (e.g. attn_pad_heads hard-masks padded heads so the model
is bit-identical — see tests/test_models.py::test_head_padding_exact).

The dry-run writes tuned cells to experiments/dryrun_tuned/ so baseline and
optimized rooflines are recorded separately (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Optional

# (arch, shape) -> ModelConfig replace() kwargs (+ "mesh_strategy").
# "*" entries apply first; shape-specific entries override them.
TUNED: dict[tuple[str, str], dict] = {
    # 24 heads % 16-way TP != 0 made GSPMD shard head_dim, turning QK^T into
    # a partial-sum with a (B,H,S,S) logits all-reduce (2.47 TB/step).
    # Padding 24->32 heads (zero-masked, bit-exact) restores head sharding.
    ("minitron_4b", "*"): {"attn_pad_heads": 32},
    # Same pathology: 12 heads -> pad to 16.
    ("qwen2_vl_2b", "*"): {"attn_pad_heads": 16},
    # 4B params x 1M-token batch is the FSDP regime: batch over BOTH mesh
    # axes, params fully sharded, no TP -> per-layer param all-gathers
    # (~0.5 GB) replace residual-stream all-reduces (~3.2 GB/layer) and no
    # head padding is needed at all.
    ("minitron_4b", "train_4k"): {"attn_pad_heads": 0,
                                  "mesh_strategy": "fsdp"},
    ("qwen2_vl_2b", "train_4k"): {"attn_pad_heads": 0,
                                  "mesh_strategy": "fsdp"},
}


def overrides_for(arch: str, shape: str) -> Optional[dict]:
    out: dict = {}
    for (a, s), kw in TUNED.items():
        if a == arch and s == "*":
            out.update(kw)
    for (a, s), kw in TUNED.items():
        if a == arch and s == shape:
            out.update(kw)
    return out or None
