"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

The assigned LM shape grid (task spec):
    train_4k     seq 4096,    global_batch 256   -> train_step
    prefill_32k  seq 32768,   global_batch 32    -> prefill_step (forward)
    decode_32k   seq 32768,   global_batch 128   -> serve_step (1 new token,
                                                   KV cache holding seq_len)
    long_500k    seq 524288,  global_batch 1     -> serve_step, sub-quadratic
                                                   archs only

Modality frontends are stubs: whisper cells add precomputed frame
embeddings (B, 1500, d_model); qwen2-vl cells use token inputs with M-RoPE
positions generated internally (the stub patchifier's position ids).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import model
from repro.models.config import ModelConfig

PyTree = Any

ENC_FRAMES = 1500          # whisper stub frontend length


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — skips recorded in EXPERIMENTS.md."""
    cell = SHAPES[shape]
    if cell.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("pure full-attention arch: O(S^2) attention at 524288 "
                       "is out of scope per task rules (sub-quadratic only)")
    return True, ""


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """Abstract inputs for the cell's step function (no allocation)."""
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len
    out: dict[str, Any] = {}
    if cell.kind in ("train", "prefill"):
        out["tokens"] = sds((b, s), jnp.int32)
        if cell.kind == "train":
            out["labels"] = sds((b, s), jnp.int32)
        if cfg.enc_dec:
            out["enc_frames"] = sds((b, ENC_FRAMES, cfg.d_model), jnp.bfloat16)
    else:                                   # decode: 1 new token + caches
        out["token"] = sds((b, 1), jnp.int32)
        out["caches"] = jax.eval_shape(
            lambda: model.init_cache(cfg, b, s,
                                     enc_len=ENC_FRAMES if cfg.enc_dec else 0))
    return out


def abstract_params(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0), cfg))


def abstract_opt_state(params: PyTree) -> PyTree:
    from repro.optim import adamw
    return jax.eval_shape(lambda p: adamw.adamw_init(p), params)
