"""Jit-able train / prefill / serve step builders shared by the trainer,
server, dry-run and benchmarks."""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw

PyTree = Any


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    remat: bool = True, compress: bool = False):
    """(params, opt_state, tokens, labels[, enc_frames]) -> updated + metrics.

    compress=True routes gradients through the int8 quantise/dequantise pair
    *before* the optimizer — under SPMD the quantised tensor is what crosses
    the DP axis (the all-reduce runs on the int8 payload's dequantised form;
    XLA schedules the cast next to the collective)."""

    def train_step(params: PyTree, opt_state: adamw.AdamWState,
                   tokens: jax.Array, labels: jax.Array,
                   enc_frames: Optional[jax.Array] = None):
        def lf(p):
            return model.loss_fn(p, tokens, labels, cfg,
                                 enc_frames=enc_frames, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        if compress:
            from repro.optim import compression
            q, scales, _ = compression.compress_grads(grads, None)
            grads = compression.decompress_grads(q, scales)
        new_params, new_opt, om = adamw.adamw_update(
            opt_cfg, grads, opt_state, params)
        return new_params, new_opt, {**metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """Full-sequence forward (the prefill_32k cells lower this)."""

    def prefill_step(params: PyTree, tokens: jax.Array,
                     enc_frames: Optional[jax.Array] = None):
        logits, _ = model.forward(params, tokens, cfg, enc_frames=enc_frames,
                                  remat=False)
        return logits

    return prefill_step


def make_serve_step(cfg: ModelConfig, sample: str = "greedy"):
    """One decode step with a KV cache: (params, token, caches) ->
    (next_token, caches, logits)."""

    def serve_step(params: PyTree, token: jax.Array, caches: PyTree):
        logits, caches = model.decode_step(params, token, caches, cfg)
        if sample == "greedy":
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        else:
            raise ValueError(sample)
        return nxt[:, None], caches

    return serve_step
