"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a module entry point (PYTHONPATH=src python -m
repro.launch.dryrun ...). The first two lines below force 512 host-platform
devices BEFORE any jax import so jax.make_mesh can build the production
meshes; never import this module from tests (they must see 1 device).

Per cell it records to experiments/dryrun/<cell>.json:
  - compile ok/fail,
  - memory_analysis (bytes per device: args/outputs/temps/code),
  - cost_analysis (per-device HLO flops / bytes accessed),
  - per-collective byte totals parsed from the post-SPMD HLO,
  - analytic MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) for §Roofline.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax                             # noqa: E402
import numpy as np                     # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs              # noqa: E402
from repro.analysis import hlo as ha   # noqa: E402
from repro.launch import specs as sp   # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402
from repro.launch import steps as st   # noqa: E402
from repro.models import sharding as sh  # noqa: E402
from repro.optim import adamw          # noqa: E402


def model_flops(cfg, cell: sp.ShapeCell) -> float:
    """6·N·D with N = active params (MoE) and D = processed tokens."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * cell.global_batch          # decode: 1 token/seq


def lower_cell(arch: str, shape: str, multi_pod: bool, tuned: bool = False):
    cfg = configs.get(arch)
    applied = None
    strategy = "2d"                       # fsdp(data) x tp(model)
    if tuned:
        from repro.launch import tuning
        import dataclasses
        applied = tuning.overrides_for(arch, shape)
        if applied:
            applied = dict(applied)
            strategy = applied.pop("mesh_strategy", "2d")
            if applied:
                cfg = dataclasses.replace(cfg, **applied)
            applied["mesh_strategy"] = strategy
    cell = sp.SHAPES[shape]
    ok, why = sp.cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    params_abs = sp.abstract_params(cfg)
    if strategy == "fsdp":
        # pure-FSDP: weights sharded over every axis, batch over every axis
        # that divides, no tensor parallelism.
        all_axes = tuple(mesh.shape.keys())
        pspecs = sh.param_specs(params_abs, cfg, mesh, fsdp_axis=all_axes,
                                model_axis=None)
        keep, rem = [], cell.global_batch
        for a in all_axes:
            if rem % mesh.shape[a] == 0:
                keep.append(a)
                rem //= mesh.shape[a]
        dspec = P(tuple(keep) if keep else None, None)
    else:
        pspecs = sh.param_specs(params_abs, cfg, mesh)
        dspec = sh.data_specs(cfg, mesh, cell.global_batch)
    psh = sh.to_shardings(pspecs, mesh)
    rep = NamedSharding(mesh, P())
    ins = sp.input_specs(cfg, shape)
    dsh = NamedSharding(mesh, dspec)

    ba = dspec[0]
    ba = (ba,) if isinstance(ba, str) else (tuple(ba) if ba else ())
    act_ctx = sh.activation_sharding(mesh, ba)
    act_ctx.__enter__()
    t0 = time.time()
    if cell.kind == "train":
        opt_abs = sp.abstract_opt_state(params_abs)
        osh = adamw.AdamWState(mu=psh, nu=psh, step=rep)
        step = st.make_train_step(cfg, adamw.AdamWConfig(), remat=True)
        args = [params_abs, opt_abs, ins["tokens"], ins["labels"]]
        in_sh = [psh, osh, dsh, dsh]
        if cfg.enc_dec:
            args.append(ins["enc_frames"])
            in_sh.append(NamedSharding(mesh, P(dspec[0], None, None)))
        lowered = jax.jit(step,
                          in_shardings=tuple(in_sh),
                          out_shardings=(psh, osh, rep)).lower(*args)
    elif cell.kind == "prefill":
        step = st.make_prefill_step(cfg)
        args = [params_abs, ins["tokens"]]
        in_sh = [psh, dsh]
        if cfg.enc_dec:
            args.append(ins["enc_frames"])
            in_sh.append(NamedSharding(mesh, P(dspec[0], None, None)))
        lowered = jax.jit(step, in_shardings=tuple(in_sh),
                          out_shardings=dsh).lower(*args)
    else:                                   # decode
        step = st.make_serve_step(cfg)
        cspec = sh.cache_specs(ins["caches"], cfg, mesh, cell.global_batch,
                               shard_seq=(cell.global_batch == 1))
        csh = sh.to_shardings(cspec, mesh)
        tok_sh = NamedSharding(mesh, P(dspec[0], None))
        lowered = jax.jit(step, in_shardings=(psh, tok_sh, csh),
                          out_shardings=(tok_sh, csh)).lower(
                              params_abs, ins["token"], ins["caches"])
    act_ctx.__exit__(None, None, None)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception as e:                  # backend-dependent
        mem["error"] = str(e)

    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k, v in ca.items():
            if k in ("flops", "bytes accessed", "transcendentals",
                     "optimal_seconds") or k.startswith("bytes accessed"):
                cost[k] = float(v)
    except Exception as e:
        cost["error"] = str(e)

    # while-aware accounting: scan bodies multiplied by trip count
    acc = ha.accumulate(compiled.as_text())
    coll = dict(acc["collective_bytes"])
    coll["total"] = acc["collective_total"]
    coll["count"] = acc["collective_count"]

    n_dev = int(np.prod(list(mesh.shape.values())))
    flops_dev = acc["dot_flops"]                  # per-device MXU flops
    bytes_dev = cost.get("bytes accessed", 0.0)   # CPU-HLO upper bound
    mf = model_flops(cfg, cell)
    terms = {
        "compute_s": flops_dev / HW["peak_flops_bf16"],
        "memory_s": bytes_dev / HW["hbm_bw"],
        "collective_s": coll["total"] / HW["ici_bw"],
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / flops_dev if flops_dev else None,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])

    return {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "devices": n_dev, "tuning": applied,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem, "cost_analysis": cost,
        "collectives": coll, "roofline": terms,
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }


def run_cell(arch: str, shape: str, multi_pod: bool, outdir: str,
             force: bool = False, tuned: bool = False) -> dict:
    os.makedirs(outdir, exist_ok=True)
    mesh_tag = "multi" if multi_pod else "single"
    path = os.path.join(outdir, f"{arch}__{shape}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        rec = lower_cell(arch, shape, multi_pod, tuned=tuned)
    except Exception as e:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
               "status": "fail", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rec, f, indent=2)
    os.replace(tmp, path)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tuned", action="store_true",
                    help="apply launch.tuning overrides (write to a "
                         "separate dir so baselines stay recorded)")
    args = ap.parse_args()
    if args.tuned and args.out == "experiments/dryrun":
        args.out = "experiments/dryrun_tuned"

    archs = list(configs.ARCHS) if args.arch == "all" else [
        configs.canonical(args.arch)]
    shapes = list(sp.SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.out, args.force,
                               tuned=args.tuned)
                tag = f"{arch} x {shape} x {rec['mesh']}"
                if rec["status"] == "ok":
                    n_ok += 1
                    r = rec["roofline"]
                    print(f"[OK]   {tag}: compile={rec['compile_s']}s "
                          f"bottleneck={r['bottleneck']} "
                          f"(c={r['compute_s']:.3e} m={r['memory_s']:.3e} "
                          f"n={r['collective_s']:.3e})", flush=True)
                    print("  memory:", rec["memory_analysis"], flush=True)
                elif rec["status"] == "skipped":
                    n_skip += 1
                    print(f"[SKIP] {tag}: {rec['reason']}", flush=True)
                else:
                    n_fail += 1
                    print(f"[FAIL] {tag}: {rec['error']}", flush=True)
    print(f"done: {n_ok} ok / {n_skip} skipped / {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
