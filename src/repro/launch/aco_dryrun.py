"""ACO-at-scale dry-run: lower + compile the city-sharded colony step for a
large TSP instance on the production mesh, and report the same roofline
terms as the LM cells (EXPERIMENTS.md §Perf cell C — the cell most
representative of the paper's technique).

    PYTHONPATH=src python -m repro.launch.aco_dryrun --n 16384 \
        --variant ants_bf16 [--multi-pod]

Variants (the §Perf ladder):
    baseline   city axis sharded over `model`; ants replicated over `data`
               (the paper's data-parallel design, mesh-tiled)
    ants       + ant population sharded over `data` (deposit psum)
    ants_bf16  + bf16 choice matrix (halves the construction gather bytes)
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json      # noqa: E402
import time      # noqa: E402

import jax                   # noqa: E402
import jax.numpy as jnp      # noqa: E402
import numpy as np           # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis import hlo as ha                  # noqa: E402
from repro.core import aco, islands                   # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402


def lower_aco(n: int, variant: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = aco.ACOConfig()                       # m = n ants, AS defaults
    ants_axis = None if variant == "baseline" else "data"
    cdt = jnp.bfloat16 if variant.endswith("bf16") else jnp.float32
    step = islands.sharded_colony_step_fn(
        mesh, n, cfg, axis="model", ants_axis=ants_axis, choice_dtype=cdt)

    nl = n // mesh.shape["model"]
    dsh = NamedSharding(mesh, P(None, "model"))
    rep = NamedSharding(mesh, P())
    dist = jax.ShapeDtypeStruct((n, n), jnp.float32)
    st = islands.ShardedColonyState(
        tau=jax.ShapeDtypeStruct((n, n), jnp.float32),
        best_tour=jax.ShapeDtypeStruct((n,), jnp.int32),
        best_len=jax.ShapeDtypeStruct((), jnp.float32),
        iteration=jax.ShapeDtypeStruct((), jnp.int32),
        key=jax.ShapeDtypeStruct((2,), jnp.uint32),
    )
    t0 = time.time()
    lowered = step.lower(dist, dist, st)
    compiled = lowered.compile()
    t_compile = time.time() - t0

    acc = ha.accumulate(compiled.as_text())
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if k in ("flops", "bytes accessed")}
    except Exception as e:
        cost = {"error": str(e)}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes"):
            mem[k] = int(getattr(ma, k, 0))
    except Exception:
        pass

    n_dev = int(np.prod(list(mesh.shape.values())))
    # one full AS iteration = n construction steps + deposit
    terms = {
        "compute_s": acc["dot_flops"] / HW["peak_flops_bf16"],
        "memory_s": cost.get("bytes accessed", 0.0) / HW["hbm_bw"],
        "collective_s": acc["collective_total"] / HW["ici_bw"],
    }
    terms["bottleneck"] = max(terms, key=terms.get)
    return {
        "workload": f"aco_sharded_colony_n{n}", "variant": variant,
        "mesh": "multi" if multi_pod else "single", "devices": n_dev,
        "status": "ok", "compile_s": round(t_compile, 2),
        "roofline": terms, "collectives": acc["collective_bytes"],
        "collective_count": acc["collective_count"],
        "memory_analysis": mem, "cost_analysis": cost,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--variant", default="all",
                    choices=["baseline", "ants", "ants_bf16", "all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/aco_dryrun")
    args = ap.parse_args()
    variants = (["baseline", "ants", "ants_bf16"] if args.variant == "all"
                else [args.variant])
    os.makedirs(args.out, exist_ok=True)
    for v in variants:
        rec = lower_aco(args.n, v, args.multi_pod)
        path = os.path.join(
            args.out, f"aco_n{args.n}__{v}__{rec['mesh']}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        t = rec["roofline"]
        print(f"[OK] {v:10s} compile={rec['compile_s']}s "
              f"c={t['compute_s']:.3e} m={t['memory_s']:.3e} "
              f"n={t['collective_s']:.3e} -> {t['bottleneck']}", flush=True)


if __name__ == "__main__":
    main()
