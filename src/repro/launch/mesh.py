"""Production mesh definitions.

Meshes are built by FUNCTIONS (never at import time) so importing this
module touches no jax device state — smoke tests keep seeing 1 CPU device;
only dryrun.py (which sets XLA_FLAGS first) materialises 256/512 devices.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_data_mesh(devices: int | None = None) -> Mesh:
    """1-D instance-sharding mesh over the host's first ``devices``
    accelerators — the solver fabric's topology (DESIGN.md §11): the
    placement layer (solver/placement.py) shards batch jobs' instance
    axes over its ``data`` axis, and the streaming service places one
    resident pool per device."""
    from repro.solver.placement import data_mesh
    return data_mesh(devices)


def make_mesh_for(devices: int | None = None, model_parallel: int = 1,
                  pods: int = 1) -> Mesh:
    """Elastic mesh: whatever devices exist, factored (pods, dp, mp)."""
    n = devices or len(jax.devices())
    assert n % (model_parallel * pods) == 0, (n, model_parallel, pods)
    dp = n // (model_parallel * pods)
    if pods > 1:
        return jax.make_mesh((pods, dp, model_parallel),
                             ("pod", "data", "model"))
    return jax.make_mesh((dp, model_parallel), ("data", "model"))


# TPU v5e-flavoured hardware constants for the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link
}
