"""In-jit convergence metrics: the statically-gated StepMetrics pytree.

``ACOConfig.metrics=True`` makes every colony step — dense
(``core.aco.colony_step``) and sparse (``sparse.aco.sparse_colony_step``)
— return a ``StepMetrics`` alongside the new state.  The engine threads it
through the batched ``while_loop`` next to the ``ColonyState`` (one row
per instance, frozen by the same done mask) and through the sharded
placement route, so live runs expose per-instance convergence state with
no host round-trip per iteration.

Exactness contract (DESIGN.md §13, tests/test_obs.py): metrics are
**read-only reductions over intermediates the step already computes** —
no extra PRNG consumption, no reordering of the state computation — so
tours / lengths / tau / keys are bitwise identical whether metrics are on
or off, on every route (solo, batched, streaming, sharded, sparse).

Every field is a scalar (f32/i32) so the pytree vmaps/shards like the
state does; fields that don't apply to a route hold 0 (``ls_accept`` with
local search off, ``ovf_*`` on the dense route, ``clamp_*`` outside MMAS).
``stagnation`` is special: a single step cannot know it (ColonyState
carries no counter), so steps emit 0 and the drivers that do carry the
counter (engine.run_batch's ``since``, run_scan's metrics carry) stamp it
in — see ``engine._run_batch_impl``.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

# field -> short meaning; doubles as the documented metrics schema the CLI
# exports and CI validates (DESIGN.md §13).
FIELDS = {
    "it_best_len": "iteration-best tour length",
    "mean_len": "mean constructed-tour length over ants",
    "best_len": "global best length after this iteration",
    "improved": "1 iff the global best improved this iteration",
    "stagnation": "consecutive non-improving iterations (driver-stamped)",
    "ls_accept": "fraction of tours local search strictly improved",
    "tau_min": "pheromone minimum",
    "tau_max": "pheromone maximum",
    "tau_mean": "pheromone mean",
    "clamp_lo": "fraction of tau entries at the MMAS lower clamp",
    "clamp_hi": "fraction of tau entries at the MMAS upper clamp",
    "ovf_adopted": "sparse overflow slots adopted this iteration",
    "ovf_evicted": "sparse overflow slots evicted this iteration",
}


class StepMetrics(NamedTuple):
    it_best_len: Array   # () f32
    mean_len: Array      # () f32
    best_len: Array      # () f32
    improved: Array      # () i32
    stagnation: Array    # () i32
    ls_accept: Array     # () f32
    tau_min: Array       # () f32
    tau_max: Array       # () f32
    tau_mean: Array      # () f32
    clamp_lo: Array      # () f32
    clamp_hi: Array      # () f32
    ovf_adopted: Array   # () i32
    ovf_evicted: Array   # () i32


_I32 = ("improved", "stagnation", "ovf_adopted", "ovf_evicted")


def zeros() -> StepMetrics:
    """Scalar zero metrics (fresh slot / metrics-off placeholder)."""
    return StepMetrics(**{
        f: jnp.asarray(0, jnp.int32 if f in _I32 else jnp.float32)
        for f in StepMetrics._fields})


def zeros_batch(b: int) -> StepMetrics:
    """(B,)-stacked zero metrics: the engine's initial carry and the
    streaming pool's resident metrics buffer."""
    return StepMetrics(**{
        f: jnp.zeros((b,), jnp.int32 if f in _I32 else jnp.float32)
        for f in StepMetrics._fields})


def tau_stats(tau: Array, clamp: Optional[tuple[Array, Array]] = None
              ) -> dict:
    """min/max/mean of a pheromone tensor plus MMAS clamp-saturation
    fractions (share of entries sitting exactly at the clip bounds —
    after ``jnp.clip`` saturated entries equal the bound bitwise).

    Works on the dense (n, n) matrix and the sparse (n, k) pages alike;
    for padded instances the statistics cover the padded buffer (phantom
    rows included) — observability, not a masked exactness surface.
    """
    out = {
        "tau_min": jnp.min(tau),
        "tau_max": jnp.max(tau),
        "tau_mean": jnp.mean(tau),
    }
    if clamp is not None:
        lo, hi = clamp
        out["clamp_lo"] = jnp.mean((tau == lo).astype(jnp.float32))
        out["clamp_hi"] = jnp.mean((tau == hi).astype(jnp.float32))
    else:
        out["clamp_lo"] = jnp.float32(0)
        out["clamp_hi"] = jnp.float32(0)
    return out


def step_metrics(lengths: Array, it_best_len: Array, best_len: Array,
                 improved: Array, tau: Array,
                 clamp: Optional[tuple[Array, Array]] = None,
                 pre_ls_lengths: Optional[Array] = None,
                 ovf_adopted: Optional[Array] = None,
                 ovf_evicted: Optional[Array] = None) -> StepMetrics:
    """Assemble one step's metrics from intermediates the step already
    holds.  ``pre_ls_lengths``: constructed-tour lengths before local
    search (None when LS is off — ls_accept reports 0)."""
    if pre_ls_lengths is None:
        ls_accept = jnp.float32(0)
    else:
        ls_accept = jnp.mean((lengths < pre_ls_lengths)
                             .astype(jnp.float32))
    zero_i = jnp.asarray(0, jnp.int32)
    return StepMetrics(
        it_best_len=it_best_len.astype(jnp.float32),
        mean_len=jnp.mean(lengths).astype(jnp.float32),
        best_len=best_len.astype(jnp.float32),
        improved=improved.astype(jnp.int32),
        stagnation=zero_i,                      # driver-stamped (see module doc)
        ls_accept=ls_accept,
        ovf_adopted=(zero_i if ovf_adopted is None
                     else ovf_adopted.astype(jnp.int32)),
        ovf_evicted=(zero_i if ovf_evicted is None
                     else ovf_evicted.astype(jnp.int32)),
        **tau_stats(tau, clamp),
    )


def to_host(mets: StepMetrics, index: Optional[int] = None) -> dict:
    """One metrics row as a plain JSON-ready dict.  ``index`` selects an
    instance row from a (B,)-stacked pytree; None reads scalar metrics."""
    import numpy as np
    out = {}
    for f, v in zip(StepMetrics._fields, mets):
        a = np.asarray(v)
        x = a if index is None else a[index]
        out[f] = int(x) if f in _I32 else float(x)
    return out
