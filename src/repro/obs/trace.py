"""Span timer, structured event log, and Chrome-trace (Perfetto) export.

Two host-side recording surfaces (DESIGN.md §13):

- ``Tracer`` — wall-clock spans and instants on named (process, thread)
  tracks, exported as Chrome trace-event JSON (``{"traceEvents": [...]}``)
  that loads directly in Perfetto / ``chrome://tracing``.  The solver
  services map devices to processes and buckets / slots to threads, so a
  streaming run renders as per-device tracks of chunk dispatches with one
  span per resident request lifetime.
- ``EventLog`` — append-only JSON-lines records (``{"t": ..., "kind": ...,
  ...}``) for the slot lifecycle (submit → admit → chunk → harvest/evict)
  and periodic stats snapshots; greppable and cheap to tail.

Both are **bounded**: a fixed event capacity with an exact ``dropped``
count, so a long-lived service cannot leak memory through its own
observability (the same discipline registry.Histogram applies to
latency samples).

``jax.profiler`` hooks live here too: ``profile_start``/``profile_stop``
wrap ``jax.profiler.start_trace``/``stop_trace`` and ``step_annotation``
wraps ``StepTraceAnnotation`` so chunk steps show up as named steps in a
TensorBoard/XPlane capture.  All jax imports are lazy — building a Tracer
never touches device state.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager
from typing import Optional


class Tracer:
    """Record spans/instants/counters on (process, thread) tracks."""

    def __init__(self, max_events: int = 200_000,
                 clock=time.perf_counter) -> None:
        self._clock = clock
        self._t0 = clock()
        self._events: deque[dict] = deque(maxlen=max_events)
        self._meta: list[dict] = []          # track-name metadata events
        self._pids: dict[str, int] = {}
        self._tids: dict[tuple[str, str], int] = {}
        self.dropped = 0

    # ------------------------------------------------------------- tracks
    def track(self, process: str = "main", thread: str = "main"
              ) -> tuple[int, int]:
        """Intern a (process, thread) pair into Chrome (pid, tid) ids and
        emit the name metadata the first time each is seen."""
        pid = self._pids.get(process)
        if pid is None:
            pid = self._pids[process] = len(self._pids)
            self._meta.append({"ph": "M", "name": "process_name",
                               "pid": pid, "tid": 0,
                               "args": {"name": process}})
        key = (process, thread)
        tid = self._tids.get(key)
        if tid is None:
            tid = self._tids[key] = sum(
                1 for (p, _) in self._tids if p == process)
            self._meta.append({"ph": "M", "name": "thread_name",
                               "pid": pid, "tid": tid,
                               "args": {"name": thread}})
        return pid, tid

    # -------------------------------------------------------------- clock
    def now_us(self) -> float:
        return (self._clock() - self._t0) * 1e6

    def to_us(self, t: float) -> float:
        """Convert a raw clock reading (same clock as this tracer's —
        time.perf_counter by default) to trace microseconds."""
        return (t - self._t0) * 1e6

    def _push(self, ev: dict) -> None:
        if len(self._events) == self._events.maxlen:
            self.dropped += 1
        self._events.append(ev)

    # ------------------------------------------------------------- events
    @contextmanager
    def span(self, name: str, process: str = "main", thread: str = "main",
             **args):
        """Complete-event span ("X") covering the with-block wall time."""
        pid, tid = self.track(process, thread)
        ts = self.now_us()
        try:
            yield
        finally:
            self._push({"ph": "X", "name": name, "pid": pid, "tid": tid,
                        "ts": ts, "dur": self.now_us() - ts,
                        "args": args})

    def complete(self, name: str, ts_us: float, dur_us: float,
                 process: str = "main", thread: str = "main", **args) -> None:
        """Record an already-measured span (e.g. a slot's residency,
        stamped at harvest from its fill timestamp)."""
        pid, tid = self.track(process, thread)
        self._push({"ph": "X", "name": name, "pid": pid, "tid": tid,
                    "ts": ts_us, "dur": dur_us, "args": args})

    def instant(self, name: str, process: str = "main",
                thread: str = "main", **args) -> None:
        pid, tid = self.track(process, thread)
        self._push({"ph": "i", "s": "t", "name": name, "pid": pid,
                    "tid": tid, "ts": self.now_us(), "args": args})

    def counter(self, name: str, process: str = "main", **values) -> None:
        """Chrome counter track ("C"): Perfetto renders it as a stacked
        area chart (occupancy, queue depth)."""
        pid, _ = self.track(process, "main")
        self._push({"ph": "C", "name": name, "pid": pid, "tid": 0,
                    "ts": self.now_us(), "args": values})

    def request_chain(self, request_id) -> list[dict]:
        """Recover one request's span chain (DESIGN.md §14): every event
        whose args carry its ``request_id`` — the retroactive ``queued``
        span, the slot-residency span, each ``chunk_dispatch`` listing it
        resident — sorted by timestamp.  The same filter an operator runs
        in the Perfetto UI, as an API."""
        out = []
        for ev in self._events:
            args = ev.get("args") or {}
            if args.get("request_id") == request_id or \
                    request_id in (args.get("request_ids") or ()):
                out.append(ev)
        return sorted(out, key=lambda e: e.get("ts", 0.0))

    # ------------------------------------------------------------- export
    def to_chrome(self) -> dict:
        return {"traceEvents": self._meta + list(self._events),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)


class EventLog:
    """Bounded in-memory JSON-lines event record, optionally mirrored to a
    file as records arrive (line-buffered append)."""

    def __init__(self, path: Optional[str] = None,
                 max_records: int = 100_000) -> None:
        self._records: deque[dict] = deque(maxlen=max_records)
        self.dropped = 0
        self._fh = open(path, "a", buffering=1) if path else None

    def emit(self, kind: str, **fields) -> None:
        rec = {"t": time.time(), "kind": kind, **fields}
        if len(self._records) == self._records.maxlen:
            self.dropped += 1
        self._records.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")

    def records(self) -> list[dict]:
        return list(self._records)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


# --------------------------------------------------------- jax.profiler
def profile_start(log_dir: str) -> None:
    """Start a jax.profiler capture (XPlane/TensorBoard trace viewer)."""
    import jax
    jax.profiler.start_trace(log_dir)


def profile_stop() -> None:
    import jax
    jax.profiler.stop_trace()


@contextmanager
def step_annotation(name: str, enabled: bool = True, **kw):
    """Name the enclosed dispatches as one profiler step (chunk steps in
    the streaming pool); a no-op passthrough when disabled so the hot path
    pays nothing without a capture running."""
    if not enabled:
        yield
        return
    import jax
    with jax.profiler.StepTraceAnnotation(name, **kw):
        yield
