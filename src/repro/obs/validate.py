"""Schema-level validators for the telemetry export surfaces.

Two checks, used by tests/test_serving.py and the CI observability lane
over the output of a short streaming replay (DESIGN.md §14):

- ``validate_chrome_trace`` — every Chrome trace event carries
  ``ph``/``pid``/``tid``/``name`` and (metadata events aside) a numeric
  ``ts``; span durations are non-negative; the payload is JSON-ready.
- ``validate_event_log`` — every JSON-lines record carries a numeric
  ``t`` timestamp and a ``kind``, and every record of a request-scoped
  kind (``REQUEST_SCOPED_KINDS``) carries ``request_id`` (plus
  ``trace_id``/``tenant``, the §14 request-propagation fields).

Both raise ``TraceValidationError`` naming the first offending record —
validators are for tests and CI, so a precise failure beats a boolean.
"""
from __future__ import annotations

import json
from numbers import Number
from typing import Iterable, Union

# Chrome trace-event phases the Tracer emits (trace.py): M metadata, X
# complete spans, i instants, C counter samples.
KNOWN_PHASES = {"M", "X", "i", "C"}

# Event-log kinds that are about one specific request and therefore must
# carry the request-scoped correlation fields.
REQUEST_SCOPED_KINDS = {"submit", "admit", "harvest", "evict",
                        "evict_waiting"}
REQUEST_FIELDS = ("request_id", "trace_id", "tenant")


class TraceValidationError(AssertionError):
    pass


def _fail(msg: str, rec) -> None:
    raise TraceValidationError(f"{msg}: {json.dumps(rec, default=str)[:300]}")


def validate_chrome_trace(trace: Union[dict, Iterable[dict]]) -> int:
    """Validate a Chrome trace dict (``{"traceEvents": [...]}``) or a raw
    event iterable; returns the number of events checked."""
    if isinstance(trace, dict):
        if "traceEvents" not in trace:
            _fail("chrome trace missing traceEvents", list(trace))
        events = trace["traceEvents"]
    else:
        events = list(trace)
    json.dumps(events)                          # JSON-ready end to end
    n = 0
    for ev in events:
        n += 1
        for field in ("ph", "pid", "tid", "name"):
            if field not in ev:
                _fail(f"trace event missing {field!r}", ev)
        if ev["ph"] not in KNOWN_PHASES:
            _fail(f"unknown phase {ev['ph']!r}", ev)
        if ev["ph"] != "M":                     # metadata has no timestamp
            if not isinstance(ev.get("ts"), Number):
                _fail("non-metadata event missing numeric ts", ev)
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), Number) or ev["dur"] < 0:
                _fail("span missing non-negative dur", ev)
    return n


def validate_event_log(records: Iterable[Union[dict, str, bytes]]) -> int:
    """Validate event-log records (dicts, or JSON-lines strings straight
    from an ``--events-out`` file); returns the number checked."""
    n = 0
    for rec in records:
        if isinstance(rec, (str, bytes)):
            try:
                rec = json.loads(rec)
            except json.JSONDecodeError:
                _fail("event-log line is not JSON", str(rec)[:200])
        n += 1
        if not isinstance(rec.get("t"), Number):
            _fail("event missing numeric t", rec)
        if not isinstance(rec.get("kind"), str):
            _fail("event missing kind", rec)
        if rec["kind"] in REQUEST_SCOPED_KINDS:
            for field in REQUEST_FIELDS:
                if field not in rec:
                    _fail(f"request-scoped {rec['kind']!r} event missing "
                          f"{field!r}", rec)
    return n


def validate_event_log_file(path: str) -> int:
    with open(path) as f:
        return validate_event_log(f)
