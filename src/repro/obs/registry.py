"""Counter/gauge/histogram registry: the host-side metrics surface.

The solver services used to keep ad-hoc stats in plain Python lists and
ints (``StreamingSolverService._latencies`` grew one float per completed
request, forever, over a long-lived service).  This module replaces them
with a tiny named-instrument registry:

- ``Counter``  — monotone int (requests submitted, slots filled, ...).
- ``Gauge``    — last-written float (current occupancy, queue depth, ...).
- ``Histogram``— **bounded**: a fixed-capacity deque of recent samples for
  percentiles, plus *exact* running ``count``/``total``/``vmax`` fields so
  means, rates and maxima never drift no matter how many samples the
  window has dropped (DESIGN.md §13).

Instruments are created on first use (``registry.counter("fills")``), so
call sites never pre-declare schemas; ``snapshot()`` emits one nested
JSON-ready dict — the stable export schema the CLI's ``--metrics-out``
writes and CI validates.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Optional


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded sample window + exact running aggregates.

    ``count``/``total``/``vmax`` are updated on every ``observe`` and are
    exact over the full stream; percentiles come from the most recent
    ``window`` samples only.  ``mean()`` is therefore exact while
    ``percentile(q)`` is a recent-window estimate — the trade the
    unbounded lists made implicitly in the other direction (exact
    percentiles, unbounded memory).
    """
    __slots__ = ("samples", "count", "total", "vmax")

    def __init__(self, window: int = 4096) -> None:
        if window < 1:
            raise ValueError(f"window {window} < 1")
        self.samples: deque[float] = deque(maxlen=window)
        self.count = 0
        self.total = 0.0
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.samples.append(v)
        self.count += 1
        self.total += v
        if v > self.vmax:
            self.vmax = v

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def max(self) -> float:
        return self.vmax if self.count else 0.0

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        # nearest-rank on the window, matching np.percentile's default
        # closely enough for latency reporting
        pos = (len(xs) - 1) * q / 100.0
        lo, hi = int(math.floor(pos)), int(math.ceil(pos))
        if lo == hi:
            return xs[lo]
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": self.mean(),
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "max": self.max(),
            "window": self.samples.maxlen,
        }


class Registry:
    """Create-on-first-use instrument registry with one snapshot schema."""

    def __init__(self) -> None:
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, window: Optional[int] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(window or 4096)
        return h

    def snapshot(self) -> dict:
        """Nested JSON-ready view: the ``registry`` section of the
        ``repro.obs/v1`` metrics schema (DESIGN.md §13)."""
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self._histograms.items())},
        }
