"""Counter/gauge/histogram registry: the host-side metrics surface.

The solver services used to keep ad-hoc stats in plain Python lists and
ints (``StreamingSolverService._latencies`` grew one float per completed
request, forever, over a long-lived service).  This module replaces them
with a tiny named-instrument registry:

- ``Counter``  — monotone int (requests submitted, slots filled, ...).
- ``Gauge``    — last-written float (current occupancy, queue depth, ...).
- ``Histogram``— **bounded**: a fixed-capacity deque of recent samples for
  percentiles, plus *exact* running ``count``/``total``/``vmax`` fields so
  means, rates and maxima never drift no matter how many samples the
  window has dropped (DESIGN.md §13).

Instruments are created on first use (``registry.counter("fills")``), so
call sites never pre-declare schemas; ``snapshot()`` emits one nested
JSON-ready dict — the stable export schema the CLI's ``--metrics-out``
writes and CI validates.

Labeled families (DESIGN.md §14): every accessor takes optional keyword
labels — ``registry.counter("slo_completed", tenant="acme")`` — and each
distinct (name, label-set) pair is its own instrument.  ``snapshot()``
renders labeled instruments under Prometheus-style flat keys
(``slo_completed{tenant="acme"}``); unlabeled names stay plain strings,
so the pre-label schema is unchanged.  ``families()`` iterates the
structured (name, labels, kind, instrument) view the ``/metrics``
exposition endpoint renders from (obs/serving.py).
"""
from __future__ import annotations

import math
from collections import deque
from typing import Iterator, Optional


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Bounded sample window + exact running aggregates.

    ``count``/``total``/``vmax`` are updated on every ``observe`` and are
    exact over the full stream; percentiles come from the most recent
    ``window`` samples only.  ``mean()`` is therefore exact while
    ``percentile(q)`` is a recent-window estimate — the trade the
    unbounded lists made implicitly in the other direction (exact
    percentiles, unbounded memory).

    Edge-case contract (tests/test_obs.py locks it):

    - empty window: ``mean``/``max``/``percentile`` all return 0.0;
    - single sample: every percentile is that sample;
    - window overflow (count > window): ``count``/``total``/``vmax``
      keep covering the *full* stream while percentiles cover only the
      surviving window — ``percentile(0)`` is the window minimum, not
      the stream minimum;
    - ``q`` outside [0, 100] clamps to the window extremes rather than
      indexing out of range.
    """
    __slots__ = ("samples", "count", "total", "vmax")

    def __init__(self, window: int = 4096) -> None:
        if window < 1:
            raise ValueError(f"window {window} < 1")
        self.samples: deque[float] = deque(maxlen=window)
        self.count = 0
        self.total = 0.0
        self.vmax = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.samples.append(v)
        self.count += 1
        self.total += v
        if v > self.vmax:
            self.vmax = v

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def max(self) -> float:
        return self.vmax if self.count else 0.0

    def percentile(self, q: float) -> float:
        if not self.samples:
            return 0.0
        # The exposition endpoint (obs/serving.py) reads from its own
        # thread; copying a deque the service thread is appending to can
        # raise "deque mutated during iteration" — retry the copy.
        for _ in range(4):
            try:
                xs = sorted(self.samples)
                break
            except RuntimeError:
                continue
        else:
            xs = sorted(list(self.samples))
        if not xs:
            return 0.0
        q = min(max(q, 0.0), 100.0)
        # nearest-rank on the window, matching np.percentile's default
        # closely enough for latency reporting
        pos = (len(xs) - 1) * q / 100.0
        lo, hi = int(math.floor(pos)), int(math.ceil(pos))
        if lo == hi:
            return xs[lo]
        frac = pos - lo
        return xs[lo] * (1.0 - frac) + xs[hi] * frac

    def summary(self) -> dict:
        return {
            "count": self.count,
            "mean": self.mean(),
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "max": self.max(),
            "window": self.samples.maxlen,
        }


# A family key is (name, sorted (label, value) tuple); the empty tuple is
# the unlabeled instrument, which snapshot() renders under the bare name.
_Key = tuple


def _key(name: str, labels: dict) -> _Key:
    return (name, tuple(sorted((str(k), str(v))
                               for k, v in labels.items())))


def _render_key(key: _Key) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Registry:
    """Create-on-first-use instrument registry with one snapshot schema."""

    def __init__(self) -> None:
        self._counters: dict[_Key, Counter] = {}
        self._gauges: dict[_Key, Gauge] = {}
        self._histograms: dict[_Key, Histogram] = {}

    def counter(self, name: str, **labels) -> Counter:
        k = _key(name, labels)
        c = self._counters.get(k)
        if c is None:
            c = self._counters[k] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        k = _key(name, labels)
        g = self._gauges.get(k)
        if g is None:
            g = self._gauges[k] = Gauge()
        return g

    def histogram(self, name: str, window: Optional[int] = None,
                  **labels) -> Histogram:
        k = _key(name, labels)
        h = self._histograms.get(k)
        if h is None:
            h = self._histograms[k] = Histogram(window or 4096)
        return h

    def families(self) -> Iterator[tuple[str, dict, str, object]]:
        """Structured (name, labels, kind, instrument) iteration — the
        view obs/serving.py renders the Prometheus text format from.
        Sorted by (name, labels) so exposition output is stable."""
        for kind, store in (("counter", self._counters),
                            ("gauge", self._gauges),
                            ("histogram", self._histograms)):
            for (name, labels) in sorted(store):
                yield name, dict(labels), kind, store[(name, labels)]

    def snapshot(self) -> dict:
        """Nested JSON-ready view: the ``registry`` section of the
        ``repro.obs/v1`` metrics schema (DESIGN.md §13).  Labeled
        instruments appear under ``name{k="v",...}`` flat keys."""
        return {
            "counters": {_render_key(k): c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {_render_key(k): g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {_render_key(k): h.summary()
                           for k, h in sorted(self._histograms.items())},
        }
