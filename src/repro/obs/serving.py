"""Serving observability plane: per-tenant SLO accounting + exposition.

The telemetry fabric (DESIGN.md §13) records everything in-process; this
module (§14) is the layer that makes a *serving* deployment observable
from the outside:

- ``SloTracker`` — folds per-request outcomes (admitted / rejected /
  expired-waiting / expired-running / completed, queue wait, end-to-end
  latency vs. deadline) into per-**tenant** labeled registry families:
  counters, bounded latency histograms, and an SLO-attainment gauge
  (fraction of terminated requests that completed within their deadline).
  The solver services call its hooks on every lifecycle transition; its
  ``summary()`` rides ``stats_snapshot`` events and service ``stats``.
- ``render_prometheus`` — the registry snapshot as Prometheus text
  exposition format (counters/gauges as-is, histograms as summaries with
  ``quantile`` labels plus ``_sum``/``_count``/``_max`` series).
- ``MetricsServer`` — a stdlib ``http.server`` background thread serving
  ``GET /metrics`` (Prometheus text), ``/healthz`` (pool liveness +
  occupancy JSON), and ``/snapshot`` (the ``repro.obs/v1`` JSON).  Wired
  into the services by ``solve_serve --metrics-port``; ``port=0`` binds
  an ephemeral port (tests), ``server.port`` reports the bound one.

Everything here is host-side and read-only over the registry: enabling
the endpoint cannot perturb a solve (the bitwise on==off contract of
tests/test_serving.py).
"""
from __future__ import annotations

import json
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

from .registry import Histogram, Registry

DEFAULT_TENANT = "default"

# Outcomes a request can terminate with (the SLO denominator): completed
# normally, evicted from the waiting queue, or evicted mid-run.
TERMINAL_OUTCOMES = ("completed", "expired_waiting", "expired_running")


class SloTracker:
    """Per-tenant SLO accounting over labeled registry families.

    Hooks mirror the request lifecycle: ``on_submit`` / ``on_reject`` at
    admission control, ``on_admit`` when a waiting request enters a slot
    (records queue wait), ``on_outcome`` at any terminal transition
    (records e2e latency and whether the deadline — when the request had
    one — was met).  Attainment is ``met / terminated`` where a request
    is *met* iff it completed and either had no deadline or finished
    within it; expired requests always count against attainment.
    """

    def __init__(self, registry: Registry, window: int = 2048) -> None:
        self.registry = registry
        self.window = window
        self._tenants: set[str] = set()

    @staticmethod
    def tenant_label(tenant: Optional[str]) -> str:
        return tenant if tenant else DEFAULT_TENANT

    @property
    def tenants(self) -> set:
        """Tenant labels seen so far (normalized)."""
        return set(self._tenants)

    def _c(self, name: str, tenant: str):
        return self.registry.counter(name, tenant=tenant)

    # ---------------------------------------------------------- lifecycle
    def on_submit(self, tenant: Optional[str]) -> str:
        t = self.tenant_label(tenant)
        self._tenants.add(t)
        self._c("slo_submitted", t).inc()
        return t

    def on_reject(self, tenant: Optional[str]) -> None:
        t = self.tenant_label(tenant)
        self._tenants.add(t)
        self._c("slo_rejected", t).inc()

    def on_admit(self, tenant: Optional[str], wait_s: float) -> None:
        t = self.tenant_label(tenant)
        self._c("slo_admitted", t).inc()
        self.registry.histogram("slo_queue_wait_s", window=self.window,
                                tenant=t).observe(wait_s)

    def on_outcome(self, tenant: Optional[str], outcome: str,
                   latency_s: float, deadline: Optional[float]) -> None:
        if outcome not in TERMINAL_OUTCOMES:
            raise ValueError(f"unknown terminal outcome {outcome!r}; "
                             f"one of {TERMINAL_OUTCOMES}")
        t = self.tenant_label(tenant)
        self._tenants.add(t)
        self._c(f"slo_{outcome}", t).inc()
        self._c("slo_terminated", t).inc()
        self.registry.histogram("slo_latency_s", window=self.window,
                                tenant=t).observe(latency_s)
        met = (outcome == "completed"
               and (deadline is None or latency_s <= deadline))
        if met:
            self._c("slo_met", t).inc()
        terminated = self._c("slo_terminated", t).value
        self.registry.gauge("slo_attainment", tenant=t).set(
            self._c("slo_met", t).value / terminated if terminated else 1.0)

    # ------------------------------------------------------------ summary
    def summary(self) -> dict:
        """Per-tenant SLO view (rides ``stats_snapshot`` events and the
        services' ``stats``): counters, attainment, and the bounded
        queue-wait / latency histogram summaries."""
        out: dict[str, dict] = {}
        for t in sorted(self._tenants):
            row = {
                "submitted": self._c("slo_submitted", t).value,
                "rejected": self._c("slo_rejected", t).value,
                "admitted": self._c("slo_admitted", t).value,
                "completed": self._c("slo_completed", t).value,
                "expired_waiting": self._c("slo_expired_waiting", t).value,
                "expired_running": self._c("slo_expired_running", t).value,
                "terminated": self._c("slo_terminated", t).value,
                "met": self._c("slo_met", t).value,
                "attainment": self.registry.gauge("slo_attainment",
                                                  tenant=t).value,
                "queue_wait_s": self.registry.histogram(
                    "slo_queue_wait_s", window=self.window,
                    tenant=t).summary(),
                "latency_s": self.registry.histogram(
                    "slo_latency_s", window=self.window,
                    tenant=t).summary(),
            }
            out[t] = row
        return out


# -------------------------------------------------------------- exposition
_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_RE = re.compile(r"[^a-zA-Z0-9_]")

QUANTILES = (50.0, 95.0, 99.0)


def _metric_name(name: str, prefix: str) -> str:
    name = _NAME_RE.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return prefix + name


def _label_str(labels: dict, extra: Optional[dict] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    inner = ",".join(
        f'{_LABEL_RE.sub("_", str(k))}="{_escape(str(v))}"'
        for k, v in sorted(items.items()))
    return "{" + inner + "}"


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _fmt(v: float) -> str:
    if v != v:
        return "NaN"
    if v in (float("inf"), float("-inf")):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v)) if isinstance(v, float) else str(v)


def render_prometheus(registry: Registry, prefix: str = "repro_") -> str:
    """Render the registry as Prometheus text exposition format 0.0.4.

    Counters/gauges map directly; each ``Histogram`` renders as a summary
    — ``name{quantile="0.5"}`` lines from the bounded sample window plus
    exact ``name_sum`` / ``name_count`` / ``name_max`` series (DESIGN.md
    §13: sums and counts never drift, quantiles are recent-window).
    ``# TYPE`` headers are emitted once per family name.
    """
    lines: list[str] = []
    typed: set[str] = set()

    def header(mname: str, kind: str) -> None:
        if mname not in typed:
            typed.add(mname)
            lines.append(f"# TYPE {mname} {kind}")

    for name, labels, kind, inst in registry.families():
        mname = _metric_name(name, prefix)
        if kind == "counter":
            header(mname, "counter")
            lines.append(f"{mname}{_label_str(labels)} {inst.value}")
        elif kind == "gauge":
            header(mname, "gauge")
            lines.append(f"{mname}{_label_str(labels)} {_fmt(inst.value)}")
        else:                                   # histogram -> summary
            assert isinstance(inst, Histogram)
            header(mname, "summary")
            for q in QUANTILES:
                ls = _label_str(labels, {"quantile": q / 100.0})
                lines.append(f"{mname}{ls} {_fmt(inst.percentile(q))}")
            lines.append(f"{mname}_sum{_label_str(labels)} "
                         f"{_fmt(inst.total)}")
            lines.append(f"{mname}_count{_label_str(labels)} {inst.count}")
            header(f"{mname}_max", "gauge")
            lines.append(f"{mname}_max{_label_str(labels)} "
                         f"{_fmt(inst.max())}")
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------- endpoint
class MetricsServer:
    """Background-thread HTTP exposition endpoint over one Telemetry.

    Routes:

    - ``GET /metrics``  — Prometheus text (``render_prometheus``);
    - ``GET /healthz``  — JSON: ``{"ok": true, "uptime_s": ...}`` merged
      with the service's ``health()`` view (pool liveness + occupancy);
    - ``GET /snapshot`` — the ``repro.obs/v1`` JSON
      (``Telemetry.snapshot()``, plus ``snapshot_extra_fn()`` fields).

    All handlers are read-only over host-side state, served by a
    ``ThreadingHTTPServer`` daemon thread: scraping cannot block or
    perturb the solve loop.  ``port=0`` binds an ephemeral port; the
    bound one is ``self.port``.  ``close()`` is idempotent.
    """

    def __init__(self, telemetry, health_fn: Optional[Callable] = None,
                 snapshot_extra_fn: Optional[Callable] = None,
                 port: int = 0, host: str = "127.0.0.1") -> None:
        self.telemetry = telemetry
        self.health_fn = health_fn
        self.snapshot_extra_fn = snapshot_extra_fn
        self._t0 = time.monotonic()
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):          # keep stdout clean
                pass

            def do_GET(self):                   # noqa: N802 (http.server)
                try:
                    path = self.path.split("?", 1)[0]
                    if path == "/metrics":
                        body = render_prometheus(
                            outer.telemetry.registry).encode()
                        ctype = "text/plain; version=0.0.4; charset=utf-8"
                    elif path == "/healthz":
                        health = {"ok": True,
                                  "uptime_s": time.monotonic() - outer._t0}
                        if outer.health_fn is not None:
                            health.update(outer.health_fn())
                        body = json.dumps(health).encode()
                        ctype = "application/json"
                    elif path == "/snapshot":
                        extra = (outer.snapshot_extra_fn()
                                 if outer.snapshot_extra_fn else None)
                        body = json.dumps(outer.telemetry.snapshot(extra),
                                          default=str).encode()
                        ctype = "application/json"
                    else:
                        self.send_error(404)
                        return
                    self.send_response(200)
                    self.send_header("Content-Type", ctype)
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                except BrokenPipeError:
                    pass
                except Exception as e:          # surface, don't crash
                    try:
                        self.send_error(500, str(e))
                    except Exception:
                        pass

        self._server = ThreadingHTTPServer((host, port), Handler)
        self._server.daemon_threads = True
        self.host = host
        self.port = self._server.server_address[1]
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="obs-metrics-server",
            daemon=True)
        self._thread.start()

    def url(self, path: str = "/metrics") -> str:
        return f"http://{self.host}:{self.port}{path}"

    def close(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._thread.join(timeout=5)
            self._server = None
