"""repro.obs — the solver telemetry fabric (DESIGN.md §13).

Three layers:

1. **In-jit metrics** (``metrics.StepMetrics``): a statically-gated
   pytree of per-iteration convergence scalars carried next to the
   ColonyState through every route; bitwise-neutral to the solve.
2. **Host-side spans + events** (``registry.Registry``, ``trace.Tracer``,
   ``trace.EventLog``): counters/gauges/bounded histograms the services'
   ``stats()`` read from, wall-clock spans on per-device/per-bucket
   tracks, and a JSON-lines slot-lifecycle event log.
3. **Export surfaces**: Chrome-trace (Perfetto-loadable) timelines,
   ``repro.obs/v1`` metrics snapshots, and ``jax.profiler`` hooks —
   surfaced by ``launch.solve_serve --metrics-out/--trace-out/
   --events-out``.
4. **Serving plane** (``serving``, DESIGN.md §14): per-tenant SLO
   accounting (``SloTracker`` over labeled registry families), the
   Prometheus text renderer, and the ``MetricsServer`` background
   ``/metrics``+``/healthz``+``/snapshot`` endpoint — wired in by
   ``solve_serve --metrics-port``; ``validate`` holds the schema-level
   trace/event well-formedness checks tests and CI run.

``Telemetry`` bundles one registry + tracer + event log; services take an
optional instance and default to a private in-memory one, so telemetry is
always cheap and never required.
"""
from __future__ import annotations

from typing import Optional

from . import metrics, registry, serving, trace, validate
from .metrics import StepMetrics
from .registry import Registry
from .serving import MetricsServer, SloTracker, render_prometheus
from .trace import EventLog, Tracer

SCHEMA = "repro.obs/v1"


class Telemetry:
    """One run's bundled observability surfaces."""

    def __init__(self, events_path: Optional[str] = None,
                 max_events: int = 200_000,
                 jax_profile_dir: Optional[str] = None) -> None:
        self.registry = Registry()
        self.tracer = Tracer(max_events=max_events)
        self.events = EventLog(events_path, max_records=max_events)
        self.jax_profile_dir = jax_profile_dir
        self._profiling = False

    # ------------------------------------------------------- jax.profiler
    @property
    def profiling(self) -> bool:
        return self._profiling

    def profile_start(self) -> None:
        if self.jax_profile_dir and not self._profiling:
            trace.profile_start(self.jax_profile_dir)
            self._profiling = True

    def profile_stop(self) -> None:
        if self._profiling:
            trace.profile_stop()
            self._profiling = False

    def step_annotation(self, name: str, **kw):
        """StepTraceAnnotation around a chunk dispatch — only pays when a
        profiler capture is actually running."""
        return trace.step_annotation(name, enabled=self._profiling, **kw)

    # ------------------------------------------------------------ exports
    def snapshot(self, extra: Optional[dict] = None) -> dict:
        """The ``repro.obs/v1`` metrics snapshot (CLI ``--metrics-out``)."""
        out = {
            "schema": SCHEMA,
            "registry": self.registry.snapshot(),
            "events_dropped": self.events.dropped,
            "trace_dropped": self.tracer.dropped,
        }
        if extra:
            out.update(extra)
        return out

    def write_metrics(self, path: str, extra: Optional[dict] = None) -> None:
        import json
        with open(path, "w") as f:
            json.dump(self.snapshot(extra), f, indent=2, default=str)

    def write_trace(self, path: str) -> None:
        self.tracer.write(path)

    def close(self) -> None:
        self.profile_stop()
        self.events.close()


__all__ = ["Telemetry", "Registry", "Tracer", "EventLog", "StepMetrics",
           "MetricsServer", "SloTracker", "render_prometheus",
           "SCHEMA", "metrics", "registry", "serving", "trace", "validate"]
