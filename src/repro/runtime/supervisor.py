"""Crash-recovery supervisor: the cluster-side fault-tolerance loop.

Wraps any checkpointed iterative workload (ACO colony, island set, LM train
loop) in a restart-on-failure driver:

- the workload exposes (init_state, step_fn, save/restore via
  CheckpointManager);
- on any exception the supervisor restores the newest checkpoint and resumes
  (up to ``max_restarts``), exactly reproducing the uninterrupted trajectory
  because every step is deterministic given the checkpointed state (RNG keys
  live in the state, data is counter-mode);
- a step *deadline* provides coarse straggler/hang mitigation: a step that
  exceeds it raises and triggers the same restore path (on a real cluster
  the replacement pod re-joins from the checkpoint; here the semantics are
  identical in-process).

tests/test_runtime.py injects crashes mid-run and asserts trajectory
equality with an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    total_steps: int
    ckpt_every: int = 10
    max_restarts: int = 5
    step_deadline_s: Optional[float] = None   # straggler/hang guard


class Supervisor:
    """Restart-on-failure driver around a (state, step) -> state loop."""

    def __init__(self, cfg: SupervisorConfig, mgr: CheckpointManager,
                 init_fn: Callable[[], Any],
                 step_fn: Callable[[Any, int], Any]):
        self.cfg = cfg
        self.mgr = mgr
        self.init_fn = init_fn
        self.step_fn = step_fn
        self.restarts = 0

    def _restore_or_init(self) -> tuple[Any, int]:
        latest = self.mgr.latest_step()
        if latest is None:
            return self.init_fn(), 0
        state, step = self.mgr.restore(self.init_fn())
        return state, step

    def _run_from(self, state: Any, start: int) -> Any:
        for i in range(start, self.cfg.total_steps):
            t0 = time.monotonic()
            state = self.step_fn(state, i)
            if (self.cfg.step_deadline_s is not None
                    and time.monotonic() - t0 > self.cfg.step_deadline_s):
                raise TimeoutError(
                    f"step {i} exceeded deadline "
                    f"{self.cfg.step_deadline_s}s (straggler/hang)")
            if (i + 1) % self.cfg.ckpt_every == 0 or i == self.cfg.total_steps - 1:
                self.mgr.save(i + 1, state)
        self.mgr.wait()
        return state

    def run(self) -> Any:
        while True:
            state, start = self._restore_or_init()
            try:
                return self._run_from(state, start)
            except KeyboardInterrupt:
                raise
            except Exception as e:                      # noqa: BLE001
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.cfg.max_restarts} restarts") from e
                # on a cluster this is where the replacement pod spins up;
                # in-process we simply loop back to restore.
                continue
