from .supervisor import Supervisor, SupervisorConfig

__all__ = ["Supervisor", "SupervisorConfig"]
