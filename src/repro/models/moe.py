"""Mixture-of-Experts layer with capacity-based sort dispatch (MaxText-style).

Dispatch is the production formulation: flatten tokens, top-k route, sort
(expert_id, token) pairs, gather into an (E, C, d) expert batch, run all
experts as one batched einsum, scatter-combine weighted outputs. The (E, C,
d) batch is the tensor whose leading axis shards over the ``model`` mesh axis
for expert parallelism (sharding.py); tokens crossing experts become XLA
all-to-alls on that axis.

Shared experts (deepseek-v3) run densely on every token. Router uses
float32 logits, top-k renormalisation, and an optional load-balancing
auxiliary loss (returned, not applied).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _act, _norm_init

Array = jax.Array
PyTree = Any


def init_moe(key: Array, cfg: ModelConfig) -> PyTree:
    d, ff = cfg.d_model, cfg.ff_expert
    e = cfg.n_experts
    ks = jax.random.split(key, 8)
    p = {
        "router": _norm_init(ks[0], (d, e), jnp.float32),
        "wi": _norm_init(ks[1], (e, d, ff), cfg.pdtype),
        "wo": _norm_init(ks[3], (e, ff, d), cfg.pdtype),
    }
    if cfg.mlp_kind == "swiglu":
        p["wg"] = _norm_init(ks[2], (e, d, ff), cfg.pdtype)
    if cfg.n_shared_experts:
        sff = cfg.ff_expert * cfg.n_shared_experts
        p["shared_wi"] = _norm_init(ks[4], (d, sff), cfg.pdtype)
        if cfg.mlp_kind == "swiglu":
            p["shared_wg"] = _norm_init(ks[5], (d, sff), cfg.pdtype)
        p["shared_wo"] = _norm_init(ks[6], (sff, d), cfg.pdtype)
    return p


def _expert_ffn(p: PyTree, x: Array, cfg: ModelConfig) -> Array:
    """x (E, C, d) -> (E, C, d), batched over experts."""
    ct = cfg.cdtype
    if cfg.mlp_kind == "swiglu":
        h = _act(jnp.einsum("ecd,edf->ecf", x, p["wg"].astype(ct)), cfg.act) \
            * jnp.einsum("ecd,edf->ecf", x, p["wi"].astype(ct))
    else:
        h = _act(jnp.einsum("ecd,edf->ecf", x, p["wi"].astype(ct)), cfg.act)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(ct))


def moe_layer(p: PyTree, x: Array, cfg: ModelConfig,
              rng: Optional[Array] = None) -> tuple[Array, Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss ()).

    Dispatch is grouped PER SEQUENCE (capacity = S*K/E*cf per sequence) and
    vmapped over the batch: the argsort/scatter/gather run group-local, so
    under SPMD the batch axis stays data-sharded and the only cross-device
    movement is the expert all-to-all on the model axis. A global-token-space
    sort (the naive formulation) makes GSPMD replicate the (T*K, d) dispatch
    buffer — measured 6.3 TB/step of collectives on dsv3 train_4k
    (EXPERIMENTS.md §Perf cell B).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    ct = cfg.cdtype
    xt = x.astype(ct)                                        # (B, S, d)

    logits = jnp.einsum("bsd,de->bse", xt.astype(jnp.float32), p["router"])
    if cfg.router_noise > 0.0 and rng is not None:
        logits = logits + cfg.router_noise * jax.random.normal(
            rng, logits.shape, jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, k)                      # (B, S, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary (Switch-style): E * sum_e f_e * P_e
    me = probs.mean((0, 1))                                  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(
        1.0) / (b * s * k)
    aux = e * jnp.sum(me * ce)

    cap = int(max(1, round(s * k / e * cfg.capacity_factor)))

    def dispatch_one(xs, es, gs):
        """One sequence: xs (S, d), es (S, K), gs (S, K) -> (E, cap, d) batch
        plus combine metadata."""
        flat_e = es.reshape(-1)                              # (S*K,)
        flat_tok = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
        flat_g = gs.reshape(-1).astype(jnp.float32)
        order = jnp.argsort(flat_e, stable=True)             # local sort
        se, st_, sg = flat_e[order], flat_tok[order], flat_g[order]
        pos = jnp.arange(s * k)
        grp_start = jnp.searchsorted(se, jnp.arange(e), side="left")
        slot = pos - grp_start[se]
        keep = slot < cap
        dst = se * cap + jnp.where(keep, slot, 0)
        ebatch = jnp.zeros((e * cap, d), ct).at[
            jnp.where(keep, dst, e * cap - 1)].add(
            jnp.where(keep[:, None], xs[st_], 0.0))
        return ebatch.reshape(e, cap, d), (st_, sg, dst, keep)

    ebatch, meta = jax.vmap(dispatch_one)(xt, idx, gate)     # (B, E, cap, d)
    from . import sharding as _sh
    ebatch = _sh.constrain_expert_batch(ebatch)
    eout = _expert_ffn_batched(p, ebatch, cfg)               # (B, E, cap, d)
    # NB: an explicit "gather experts before combine" reshard was tried here
    # (sharding.constrain_combine) and REFUTED: 93.2s vs 84.5s collective —
    # GSPMD's derived pattern beats the full-buffer all-gather. See
    # EXPERIMENTS.md §Perf cell B iteration 4.
    eout = _sh.constrain_expert_batch(eout)

    def combine_one(eo, m):
        st_, sg, dst, keep = m
        eo_flat = eo.reshape(e * cap, d)
        contrib = jnp.where(keep[:, None],
                            eo_flat[dst] * sg[:, None].astype(ct), 0.0)
        return jnp.zeros((s, d), ct).at[st_].add(contrib.astype(ct))

    out = jax.vmap(combine_one)(eout, meta)                  # (B, S, d)

    if cfg.n_shared_experts:
        if cfg.mlp_kind == "swiglu":
            hsh = _act(xt @ p["shared_wg"].astype(ct), cfg.act) \
                * (xt @ p["shared_wi"].astype(ct))
        else:
            hsh = _act(xt @ p["shared_wi"].astype(ct), cfg.act)
        out = out + hsh @ p["shared_wo"].astype(ct)

    return out, aux


def _expert_ffn_batched(p: PyTree, x: Array, cfg: ModelConfig) -> Array:
    """x (B, E, C, d) -> (B, E, C, d); experts broadcast over the batch."""
    ct = cfg.cdtype
    if cfg.mlp_kind == "swiglu":
        h = _act(jnp.einsum("becd,edf->becf", x, p["wg"].astype(ct)),
                 cfg.act) * jnp.einsum("becd,edf->becf", x,
                                       p["wi"].astype(ct))
    else:
        h = _act(jnp.einsum("becd,edf->becf", x, p["wi"].astype(ct)),
                 cfg.act)
    return jnp.einsum("becf,efd->becd", h, p["wo"].astype(ct))


def moe_layer_dense_eval(p: PyTree, x: Array, cfg: ModelConfig) -> Array:
    """Oracle: run every expert on every token, combine by full router probs
    restricted to top-k. Used by tests to validate the sparse dispatch."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    ct = cfg.cdtype
    xt = x.reshape(-1, d).astype(ct)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    mask = jnp.zeros_like(probs).at[jnp.arange(xt.shape[0])[:, None], idx].set(gate)
    every = _expert_ffn(p, jnp.broadcast_to(xt, (e,) + xt.shape), cfg)  # (E,T,d)
    out = jnp.einsum("te,etd->td", mask.astype(ct), every)
    if cfg.n_shared_experts:
        if cfg.mlp_kind == "swiglu":
            hsh = _act(xt @ p["shared_wg"].astype(ct), cfg.act) \
                * (xt @ p["shared_wi"].astype(ct))
        else:
            hsh = _act(xt @ p["shared_wi"].astype(ct), cfg.act)
        out = out + hsh @ p["shared_wo"].astype(ct)
    return out.reshape(b, s, d)
