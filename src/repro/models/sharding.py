"""Partition rules: map every parameter / batch / cache leaf to a
PartitionSpec on the production mesh (axes: optional "pod", "data", "model").

Strategy (DESIGN.md §4):
- ``model`` = tensor parallelism: attention heads (fallback: head_dim, then
  replicate), MLP d_ff, MoE experts (fallback: expert-internal d_ff when
  n_experts < axis size, e.g. grok's 8 experts on a 16-way axis), mamba
  inner channels / SSD heads, vocab (fallback: d_model when the vocab is not
  divisible, e.g. whisper's 51865).
- ``data`` = FSDP: the weight's d_model-like dimension is sharded over data
  and all-gathered per layer inside the scan (ZeRO-3 style); optimizer
  states inherit the same specs (ZeRO is free given the param specs).
- ``pod`` = plain data parallelism (batch), replicated params.

Stacked scan parameters carry a leading period axis -> specs are left-padded
with None to the leaf ndim. Every rule checks divisibility and degrades to
replication rather than failing, so *any* (arch x mesh) combination lowers.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig

PyTree = Any


def _div(n: int, mesh: Mesh, axis: Optional[str]) -> bool:
    if axis is None:
        return True
    return n % int(np.prod([mesh.shape[a] for a in _tup(axis)])) == 0


def _tup(axis) -> tuple:
    if axis is None:
        return ()
    return (axis,) if isinstance(axis, str) else tuple(axis)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params: PyTree, cfg: ModelConfig, mesh: Mesh,
                fsdp_axis=("data",),
                model_axis: Optional[str] = "model") -> PyTree:
    """PartitionSpec pytree matching ``params`` (shapes only are consulted).

    fsdp_axis may be a single axis or a tuple (pure-FSDP strategy shards
    weights over BOTH mesh axes and keeps tensor dims unsharded);
    model_axis=None disables tensor parallelism entirely.
    """
    fa = _tup(fsdp_axis)
    fa = tuple(a for a in fa if a in mesh.shape) or None
    ma = model_axis

    def fsdp(dim: int):
        return fa if fa and dim and _div(dim, mesh, fa) else None

    def tp(dim: int):
        return ma if ma and ma in mesh.shape and dim and _div(dim, mesh, ma) else None

    def rule(path: str, shape: Sequence[int]) -> P:
        nd = len(shape)
        name = path.rsplit("/", 1)[-1]
        in_moe = "/moe/" in path or path.endswith("moe")

        def pad(spec: tuple) -> P:
            return P(*((None,) * (nd - len(spec)) + spec))

        # ---- embeddings / heads
        if name == "embed":
            v, d = shape[-2:]
            if tp(v):
                return pad((ma, fsdp(d)))
            return pad((None, tp(d)))
        if name == "lm_head":
            d, v = shape[-2:]
            if tp(v):
                return pad((fsdp(d), ma))
            return pad((tp(d), None))

        # ---- attention (GQA)
        if name == "wq" and nd >= 3:
            d, h, dh = shape[-3:]
            if tp(h):
                return pad((fsdp(d), ma, None))
            if tp(dh):
                return pad((fsdp(d), None, ma))
            return pad((fsdp(d), None, None))
        if name in ("wk", "wv") and nd >= 3:
            d, kv, dh = shape[-3:]
            if tp(kv):
                return pad((fsdp(d), ma, None))
            return pad((fsdp(d), None, None))
        if name == "wo" and nd >= 3 and not in_moe:
            h, dh, d = shape[-3:]
            if tp(h):
                return pad((ma, None, fsdp(d)))
            if tp(dh):
                return pad((None, ma, fsdp(d)))
            return pad((None, None, fsdp(d)))

        # ---- MLA projections (2-D)
        if name in ("wq_a", "wkv_a"):
            d, r = shape[-2:]
            return pad((fsdp(d), tp(r)))
        if name in ("wq_b", "wkv_b"):
            r, hq = shape[-2:]
            return pad((fsdp(r), tp(hq)))
        if name == "wq" and nd == 2:        # MLA dense q
            d, hq = shape[-2:]
            return pad((fsdp(d), tp(hq)))
        if name == "wo" and nd == 2 and not in_moe:
            hv, d = shape[-2:]
            return pad((tp(hv), fsdp(d)))

        # ---- MoE
        if in_moe:
            if name == "router":
                return pad((None, None))
            if name in ("wi", "wg") and nd >= 3:
                e, d, f = shape[-3:]
                if tp(e):
                    # FSDP on the ff dim, NOT on d: a d-sharded expert weight
                    # turns every expert einsum into a partial-sum with a
                    # (B,E,cap,f) all-reduce over the data axis.
                    return pad((ma, None, fsdp(f)))
                return pad((None, fsdp(d), tp(f)))
            if name == "wo" and nd >= 3:
                e, f, d = shape[-3:]
                if tp(e):
                    return pad((ma, fsdp(f), None))
                return pad((None, tp(f), fsdp(d)))
            if name in ("shared_wi", "shared_wg"):
                d, f = shape[-2:]
                return pad((fsdp(d), tp(f)))
            if name == "shared_wo":
                f, d = shape[-2:]
                return pad((tp(f), fsdp(d)))

        # ---- dense MLP (2-D)
        if name in ("wi", "wg"):
            d, f = shape[-2:]
            return pad((fsdp(d), tp(f)))
        if name == "wo" and nd == 2:
            f, d = shape[-2:]
            return pad((tp(f), fsdp(d)))

        # ---- mamba
        if name == "in_proj":
            d, z = shape[-2:]
            return pad((fsdp(d), tp(z)))
        if name == "out_proj":
            din, d = shape[-2:]
            return pad((tp(din), fsdp(d)))
        if name == "conv_w":
            k, c = shape[-2:]
            return pad((None, tp(c)))
        if name in ("conv_b", "norm_scale"):
            return pad((tp(shape[-1]),))
        if name in ("A_log", "D", "dt_bias"):
            return pad((tp(shape[-1]),))

        # ---- misc dense (mtp proj, enc_in_proj)
        if name == "proj" or name == "enc_in_proj":
            a, b = shape[-2:]
            return pad((fsdp(a), tp(b)))

        # ---- norms & anything else: replicate
        return P()

    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [rule(_path_str(p), x.shape) for p, x in leaves]
    return jax.tree.unflatten(treedef, specs)


def batch_axes(mesh: Mesh) -> tuple:
    """Data-parallel axes for the batch dim: pod (if present) + data."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_specs(cfg: ModelConfig, mesh: Mesh, batch: int) -> P:
    """Spec for (B, S) token batches — batch over every DP axis that divides."""
    axes = [a for a in batch_axes(mesh)]
    keep: list = []
    rem = batch
    for a in axes:
        if rem % mesh.shape[a] == 0:
            keep.append(a)
            rem //= mesh.shape[a]
    return P(tuple(keep) if keep else None, None)


def cache_specs(caches: PyTree, cfg: ModelConfig, mesh: Mesh, batch: int,
                shard_seq: bool = False) -> PyTree:
    """Decode-cache specs. Default: batch over DP axes, kv-heads/latent over
    model when divisible. shard_seq=True (long-context, batch=1): the cache
    *sequence* axis shards over data — the distributed flash-decode layout.
    """
    bspec = data_specs(cfg, mesh, batch)[0]

    def rule(path: str, shape) -> P:
        nd = len(shape)
        name = path.rsplit("/", 1)[-1]
        if name in ("len", "step") or nd == 0:
            return P()
        if name in ("k", "v"):                    # (B, T, KV, dh)
            kv = shape[-2]
            kvs = "model" if kv % mesh.shape["model"] == 0 else None
            if shard_seq:
                return P(None, "data", kvs, None)
            return P(bspec, None, kvs, None)
        if name == "ckv":                         # (B, T, rank)
            return P(None, "data", None) if shard_seq else P(bspec, None, None)
        if name == "k_rope":                      # (B, T, 1, rdim)
            return P(None, "data", None, None) if shard_seq else P(bspec, None, None, None)
        if name == "conv":                        # (B, K-1, conv_dim)
            c = shape[-1]
            cs = "model" if c % mesh.shape["model"] == 0 else None
            return P(bspec, None, cs)
        if name == "h":                           # (B, H, P, N)
            hh = shape[-3]
            hs = "model" if hh % mesh.shape["model"] == 0 else None
            return P(bspec, hs, None, None)
        return P()

    leaves, treedef = jax.tree_util.tree_flatten_with_path(caches)
    # stacked period axis: leaves under blocks/ have one extra leading dim
    out = []
    for p, x in leaves:
        ps = _path_str(p)
        spec = rule(ps, x.shape[1:] if ps.startswith("blocks") and x.ndim > 0
                    and "step" not in ps else x.shape)
        if ps.startswith("blocks") and x.ndim > len(spec):
            spec = P(*((None,) * (x.ndim - len(spec)) + tuple(spec)))
        out.append(spec)
    return jax.tree.unflatten(treedef, out)


def to_shardings(specs: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# --------------------------------------------------------------------------
# Activation-sharding context: without an explicit constraint inside the
# layer scan, GSPMD may legally choose weight-stationary propagation and
# REPLICATE the token batch on every device (observed: 16x extra FLOPs on
# the 16x16 mesh). The launcher wraps tracing in activation_sharding(); the
# model calls constrain_tokens() on the (B, S, d) stream each layer.
# --------------------------------------------------------------------------
import contextlib

_ACT_CTX: list = []


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, batch_axes_: tuple):
    _ACT_CTX.append((mesh, tuple(batch_axes_)))
    try:
        yield
    finally:
        _ACT_CTX.pop()


def constrain_expert_batch(x):
    """(B, E, cap, d) expert-dispatch buffer: batch over DP axes, experts
    over the model axis (the boundary whose reshard IS the MoE all-to-all)."""
    if not _ACT_CTX or x.ndim != 4:
        return x
    mesh, ba = _ACT_CTX[-1]
    espec = "model" if ("model" in mesh.shape
                        and x.shape[1] % mesh.shape["model"] == 0) else None
    bspec = None
    if ba:
        total = int(np.prod([mesh.shape[a] for a in ba]))
        if x.shape[0] % total == 0:
            bspec = ba
    if bspec is None and espec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(bspec, espec, None, None)))


def constrain_combine(x):
    """(B, E, cap, d) expert OUTPUT before the combine-gather: batch stays on
    DP axes, experts explicitly UNsharded — one bf16 all-gather over the
    model axis instead of the f32 (B, S*K, d) partial-sum pattern GSPMD
    otherwise derives for a gather from an E-sharded buffer."""
    if not _ACT_CTX or x.ndim != 4:
        return x
    mesh, ba = _ACT_CTX[-1]
    bspec = None
    if ba:
        total = int(np.prod([mesh.shape[a] for a in ba]))
        if x.shape[0] % total == 0:
            bspec = ba
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(bspec, None, None, None)))


def constrain_tokens(x):
    """Pin a (B, ...) activation to batch-over-DP-axes sharding (no-op
    outside an activation_sharding context or when B does not divide)."""
    if not _ACT_CTX:
        return x
    mesh, ba = _ACT_CTX[-1]
    if not ba:
        return x
    total = int(np.prod([mesh.shape[a] for a in ba]))
    if x.ndim == 0 or x.shape[0] % total != 0:
        return x
    spec = P(ba, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
