"""Transformer building blocks: norms, rotary embeddings, attention
(GQA / sliding-window / MLA / cross), dense MLPs.

All functions are pure: ``params`` pytrees in, arrays out. Initialisation
mirrors common practice (truncated-normal 0.02, zero-init output projs are
skipped for simplicity). Softmax and norm statistics run in float32
regardless of compute dtype.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

Array = jax.Array
PyTree = Any
INIT_SCALE = 0.02


def _norm_init(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * INIT_SCALE


# ----------------------------------------------------------------- norms
def init_norm(cfg: ModelConfig, d: int) -> PyTree:
    if cfg.norm == "nonparam_ln":          # olmo: no scale, no bias
        return {}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), cfg.pdtype),
                "bias": jnp.zeros((d,), cfg.pdtype)}
    return {"scale": jnp.ones((d,), cfg.pdtype)}     # rmsnorm


def apply_norm(p: PyTree, x: Array, cfg: ModelConfig) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        y = y * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        if cfg.norm == "layernorm":
            y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ------------------------------------------------------------------ rope
def rope_freqs(dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (B, S, H, Dh), positions (B, S) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions3: Array, theta: float,
                sections: tuple[int, int, int]) -> Array:
    """Qwen2-VL multimodal RoPE: positions3 (3, B, S) for (t, h, w);
    the dh/2 frequency slots are split into t/h/w sections."""
    dh = x.shape[-1]
    half = dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(dh, theta)                        # (half,)
    # choose which position stream drives each frequency slot
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections),
                        total_repeat_length=half)        # (half,)
    pos = positions3.astype(jnp.float32)                 # (3, B, S)
    ang = jnp.take(pos, sec_id, axis=0)                  # (half, B, S) stream per slot
    ang = jnp.moveaxis(ang, 0, -1) * freqs               # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def positions_like(tokens: Array, offset: Array | int = 0) -> Array:
    b, s = tokens.shape[:2]
    return jnp.arange(s, dtype=jnp.int32)[None, :] + offset


# ------------------------------------------------------------- attention
def init_attention(key: Array, cfg: ModelConfig) -> PyTree:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(key, 8)
    if cfg.attn_kind == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        p = {
            "wkv_a": _norm_init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim), cfg.pdtype),
            "kv_norm": jnp.ones((cfg.kv_lora_rank,), cfg.pdtype),
            "wkv_b": _norm_init(ks[3], (cfg.kv_lora_rank,
                                        h * (cfg.qk_nope_dim + cfg.v_head_dim)),
                                cfg.pdtype),
            "wo": _norm_init(ks[4], (h * cfg.v_head_dim, d), cfg.pdtype),
        }
        if cfg.q_lora_rank:
            p["wq_a"] = _norm_init(ks[0], (d, cfg.q_lora_rank), cfg.pdtype)
            p["q_norm"] = jnp.ones((cfg.q_lora_rank,), cfg.pdtype)
            p["wq_b"] = _norm_init(ks[1], (cfg.q_lora_rank, h * qk), cfg.pdtype)
        else:
            p["wq"] = _norm_init(ks[0], (d, h * qk), cfg.pdtype)
        return p
    hp = cfg.attn_pad_heads or h
    assert hp >= h
    wq = _norm_init(ks[0], (d, hp, dh), cfg.pdtype)
    wo = _norm_init(ks[3], (hp, dh, d), cfg.pdtype)
    if hp > h:          # padded head slices start (and stay) exactly zero
        wq = wq.at[:, h:, :].set(0.0)
        wo = wo.at[h:, :, :].set(0.0)
    return {
        "wq": wq,
        "wk": _norm_init(ks[1], (d, kv, dh), cfg.pdtype),
        "wv": _norm_init(ks[2], (d, kv, dh), cfg.pdtype),
        "wo": wo,
    }


def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array],
          softcap: float = 0.0) -> Array:
    """q (B,S,H,Dh), k/v (B,T,H,Dh) already head-expanded. f32 softmax."""
    dh = q.shape[-1]
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(dh))
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _expand_kv(k: Array, n_heads: int, cfg: Optional[ModelConfig] = None
               ) -> Array:
    """(B,T,KV,Dh) -> (B,T,Hp,Dh) by GQA group mapping.

    With head padding, the logical group mapping (head i -> kv i // (H/KV))
    must be preserved for the real heads; padded heads reuse group 0 (their
    output is hard-masked anyway)."""
    kvh = k.shape[2]
    hp = n_heads
    h_logical = cfg.n_heads if cfg is not None else n_heads
    if kvh == hp:
        return k
    if hp == h_logical:
        return jnp.repeat(k, hp // kvh, axis=2)
    idx = jnp.concatenate([
        jnp.arange(h_logical) // max(h_logical // kvh, 1),
        jnp.zeros((hp - h_logical,), jnp.int32)]).astype(jnp.int32)
    return k[:, :, idx, :]


def _head_mask(cfg: ModelConfig, hp: int, dtype) -> Optional[Array]:
    """(Hp,) 1.0 for logical heads, 0.0 for padding (None when unpadded)."""
    if hp == cfg.n_heads:
        return None
    return (jnp.arange(hp) < cfg.n_heads).astype(dtype)


def causal_mask(s: int, t: int, offset: int = 0, window: int = 0) -> Array:
    """(1,1,S,T) boolean; query i attends key j iff j <= i+offset and within
    the sliding window when window > 0."""
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > qi - window
    return m[None, None]


def attention(p: PyTree, x: Array, cfg: ModelConfig, positions: Array,
              cache: Optional[PyTree] = None,
              kv_src: Optional[Array] = None,
              is_cross: bool = False) -> tuple[Array, Optional[PyTree]]:
    """Self- or cross-attention with optional decode cache.

    cache (self-attn): {"k": (B,T,KV,Dh), "v": ..., "len": ()} — ring buffer
    when cfg.window > 0 (SWA decode state is O(window)).
    cross-attn: cache = {"k","v"} precomputed from encoder output.
    """
    b, s, d = x.shape
    kvh, dh = cfg.n_kv, cfg.d_head
    hp = p["wq"].shape[1]                       # physical (maybe padded) heads
    hmask = _head_mask(cfg, hp, cfg.cdtype)
    ct = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bshk", x.astype(ct), p["wq"].astype(ct))

    def project_out(out):
        if hmask is not None:                   # zero padded heads: exact
            out = out * hmask[None, None, :, None]
        return jnp.einsum("bshd,hdk->bsk", out, p["wo"].astype(ct))

    if kv_src is not None or is_cross:          # cross attention
        if cache is not None and "k" in cache:
            k, v = cache["k"], cache["v"]
        else:
            k = jnp.einsum("btd,dhk->bthk", kv_src.astype(ct), p["wk"].astype(ct))
            v = jnp.einsum("btd,dhk->bthk", kv_src.astype(ct), p["wv"].astype(ct))
            cache = {"k": k, "v": v}
        out = _sdpa(q, _expand_kv(k, hp, cfg), _expand_kv(v, hp, cfg), None,
                    cfg.logit_softcap)
        return project_out(out), cache

    k = jnp.einsum("bsd,dhk->bshk", x.astype(ct), p["wk"].astype(ct))
    v = jnp.einsum("bsd,dhk->bshk", x.astype(ct), p["wv"].astype(ct))
    if cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        pos3 = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)

    if cache is None:                           # full-sequence (train/prefill)
        mask = (causal_mask(s, s, 0, cfg.window) if cfg.causal else None)
        out = _sdpa(q, _expand_kv(k, hp, cfg), _expand_kv(v, hp, cfg), mask,
                    cfg.logit_softcap)
        new_cache = None
    else:                                       # single-token decode
        t = cache["k"].shape[1]
        if cfg.window > 0 and t == cfg.window:  # O(window) ring buffer
            ck = jnp.roll(cache["k"], -1, axis=1).at[:, -1].set(k[:, 0])
            cv = jnp.roll(cache["v"], -1, axis=1).at[:, -1].set(v[:, 0])
            # newest entry lives at slot t-1; valid slots are the last len+1
            mask = jnp.arange(t)[None, None, None, :] >= (
                t - jnp.minimum(cache["len"] + 1, t))
        else:
            idx = cache["len"]
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, 1)
            t = ck.shape[1]
            kj = jnp.arange(t)[None, None, None, :]
            mask = kj <= idx
            if cfg.window > 0:
                mask &= kj > idx - cfg.window
        out = _sdpa(q, _expand_kv(ck, hp, cfg), _expand_kv(cv, hp, cfg), mask,
                    cfg.logit_softcap)
        new_cache = {"k": ck, "v": cv, "len": cache["len"] + 1}
    return project_out(out), new_cache


def mla_attention(p: PyTree, x: Array, cfg: ModelConfig, positions: Array,
                  cache: Optional[PyTree] = None
                  ) -> tuple[Array, Optional[PyTree]]:
    """DeepSeek-V3 Multi-head Latent Attention.

    Cache stores only the compressed latent (B, T, kv_lora_rank) plus the
    shared rope key (B, T, qk_rope_dim): 576 values/token vs 2*H*Dh = 32768
    for MHA at dsv3 scale — the 57x KV-cache compression that makes 32k-decode
    shardable.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    ct = cfg.cdtype
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    xc = x.astype(ct)

    if cfg.q_lora_rank:
        ql = xc @ p["wq_a"].astype(ct)
        ql = _rms(ql, p["q_norm"])
        q = (ql @ p["wq_b"].astype(ct)).reshape(b, s, h, nope + rdim)
    else:
        q = (xc @ p["wq"].astype(ct)).reshape(b, s, h, nope + rdim)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = xc @ p["wkv_a"].astype(ct)                  # (B,S,rank+rdim)
    ckv, k_rope = kv_a[..., : cfg.kv_lora_rank], kv_a[..., cfg.kv_lora_rank:]
    ckv = _rms(ckv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)

    if cache is not None:
        idx = cache["len"]
        ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, idx, 1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope, idx, 1)
        new_cache = {"ckv": ckv, "k_rope": k_rope, "len": cache["len"] + 1}
        t = ckv.shape[1]
        mask = jnp.arange(t)[None, None, None, :] <= idx
    else:
        new_cache = None
        t = s
        mask = causal_mask(s, s) if cfg.causal else None

    # decompress keys/values from the latent (weight-absorbed form would fold
    # wkv_b into q/o; kept explicit for clarity — same FLOPs either way at
    # prefill, see EXPERIMENTS.md §Perf for the decode absorption variant).
    kvb = (ckv @ p["wkv_b"].astype(ct)).reshape(b, t, h, nope + vdim)
    k_nope, v = kvb[..., :nope], kvb[..., nope:]

    qf = jnp.concatenate([q_nope, q_rope], -1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, t, h, rdim))], -1)
    logits = jnp.einsum("bshd,bthd->bhst", qf, kf).astype(jnp.float32)
    logits = logits / jnp.sqrt(jnp.float32(nope + rdim))
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, -1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(b, s, h * vdim)
    return out @ p["wo"].astype(ct), new_cache


def _rms(x: Array, scale: Array) -> Array:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- MLPs
def init_mlp(key: Array, cfg: ModelConfig, d_ff: int) -> PyTree:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    if cfg.mlp_kind == "swiglu":
        return {"wi": _norm_init(ks[0], (d, d_ff), cfg.pdtype),
                "wg": _norm_init(ks[1], (d, d_ff), cfg.pdtype),
                "wo": _norm_init(ks[2], (d_ff, d), cfg.pdtype)}
    return {"wi": _norm_init(ks[0], (d, d_ff), cfg.pdtype),
            "wo": _norm_init(ks[2], (d_ff, d), cfg.pdtype)}


def _act(x: Array, act: str) -> Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "relu2":          # nemotron/minitron squared relu
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(act)


def mlp(p: PyTree, x: Array, cfg: ModelConfig) -> Array:
    ct = cfg.cdtype
    xc = x.astype(ct)
    if cfg.mlp_kind == "swiglu":
        hdn = _act(xc @ p["wg"].astype(ct), cfg.act) * (xc @ p["wi"].astype(ct))
    else:
        hdn = _act(xc @ p["wi"].astype(ct), cfg.act)
    return hdn @ p["wo"].astype(ct)
