"""Model assembly: embeddings, scan-stacked heterogeneous blocks, losses,
prefill/decode.

Layer heterogeneity (jamba 1:7, dsv3 dense-prefix) is handled by scanning
over *periods*: parameters are stacked with a leading ``n_periods`` axis and
the period body (len(cfg.period) layers) is unrolled inside the scan. This
keeps the lowered HLO size O(period) instead of O(n_layers) — essential for
compiling 61-72 layer configs — while still permitting per-layer block kinds.

Decode caches mirror the same layout: leaves stacked over periods, scanned
jointly with the parameters.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import layers, moe, ssm
from .config import LayerSpec, ModelConfig

Array = jax.Array
PyTree = Any


# ============================================================== init
def _init_layer(key: Array, spec: LayerSpec, cfg: ModelConfig,
                dense_ff: Optional[int] = None) -> PyTree:
    ks = jax.random.split(key, 6)
    p: dict[str, PyTree] = {"ln1": layers.init_norm(cfg, cfg.d_model)}
    if spec.kind == "mamba":
        p["mamba"] = ssm.init_mamba(ks[0], cfg)
    elif cfg.attn_kind == "mla":
        p["attn"] = layers.init_attention(ks[0], cfg)
    else:
        p["attn"] = layers.init_attention(ks[0], cfg)
    if spec.cross_attn:
        p["ln_x"] = layers.init_norm(cfg, cfg.d_model)
        p["xattn"] = layers.init_attention(ks[1], cfg)
    if spec.moe:
        p["ln2"] = layers.init_norm(cfg, cfg.d_model)
        p["moe"] = moe.init_moe(ks[2], cfg)
    elif cfg.d_ff > 0:
        p["ln2"] = layers.init_norm(cfg, cfg.d_model)
        p["mlp"] = layers.init_mlp(ks[2], cfg, dense_ff or cfg.d_ff)
    return p


def init_params(key: Array, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 16)
    d = cfg.d_model
    params: dict[str, PyTree] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, d), jnp.float32)
                  * layers.INIT_SCALE).astype(cfg.pdtype),
        "final_norm": layers.init_norm(cfg, d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(ks[1], (d, cfg.vocab),
                                               jnp.float32)
                             * layers.INIT_SCALE).astype(cfg.pdtype)
    # prefix (unrolled)
    if cfg.prefix:
        params["prefix"] = [
            _init_layer(jax.random.fold_in(ks[2], i), s, cfg,
                        dense_ff=cfg.ff_dense)
            for i, s in enumerate(cfg.prefix)
        ]
    # periodic body: stack per-period params
    def one_period(pk):
        kk = jax.random.split(pk, len(cfg.period))
        return [
            _init_layer(kk[i], s, cfg) for i, s in enumerate(cfg.period)
        ]
    periods = [one_period(jax.random.fold_in(ks[3], i))
               for i in range(cfg.n_periods)]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *periods)

    if cfg.enc_dec:
        enc_layers = [
            _init_layer(jax.random.fold_in(ks[4], i), LayerSpec(), cfg)
            for i in range(cfg.n_enc_layers)
        ]
        params["enc_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                            *enc_layers)
        params["enc_final_norm"] = layers.init_norm(cfg, d)
        params["enc_in_proj"] = (jax.random.normal(ks[5], (d, d), jnp.float32)
                                 * layers.INIT_SCALE).astype(cfg.pdtype)
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": (jax.random.normal(ks[6], (2 * d, d), jnp.float32)
                     * layers.INIT_SCALE).astype(cfg.pdtype),
            "block": _init_layer(ks[7], LayerSpec(), cfg),
            "norm": layers.init_norm(cfg, d),
        }
    return params


# ============================================================== forward
def _apply_layer(spec: LayerSpec, p: PyTree, x: Array, cfg: ModelConfig,
                 positions: Array, cache: Optional[PyTree],
                 enc_out: Optional[Array], causal: bool = True
                 ) -> tuple[Array, Optional[PyTree], Array]:
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict[str, PyTree] = {}
    h = layers.apply_norm(p["ln1"], x, cfg)
    if spec.kind == "mamba":
        out, c = ssm.mamba_forward(p["mamba"], h, cfg,
                                   None if cache is None else cache["mamba"])
        if c is not None and cache is not None:
            new_cache["mamba"] = c
    elif cfg.attn_kind == "mla":
        out, c = layers.mla_attention(
            p["attn"], h, cfg, positions,
            None if cache is None else cache["attn"])
        if cache is not None:
            new_cache["attn"] = c
    else:
        lcfg = cfg if causal else _noncausal(cfg)
        out, c = layers.attention(
            p["attn"], h, lcfg, positions,
            None if cache is None else cache["attn"])
        if cache is not None:
            new_cache["attn"] = c
    x = x + out
    if spec.cross_attn:
        hx = layers.apply_norm(p["ln_x"], x, cfg)
        xout, xc = layers.attention(
            p["xattn"], hx, cfg, positions,
            None if cache is None else cache.get("xattn"), kv_src=enc_out,
            is_cross=True)
        x = x + xout
        if cache is not None:
            new_cache["xattn"] = xc
    if spec.moe:
        h2 = layers.apply_norm(p["ln2"], x, cfg)
        mout, aux = moe.moe_layer(p["moe"], h2, cfg)
        x = x + mout
    elif cfg.d_ff > 0:
        h2 = layers.apply_norm(p["ln2"], x, cfg)
        x = x + layers.mlp(p["mlp"], h2, cfg)
    return x, (new_cache if cache is not None else None), aux


@functools.lru_cache(maxsize=None)
def _noncausal(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, causal=False)


def _run_body(params: PyTree, x: Array, cfg: ModelConfig, positions: Array,
              caches: Optional[PyTree], enc_out: Optional[Array],
              remat: bool = False) -> tuple[Array, Optional[PyTree], Array]:
    """prefix (unrolled) + periodic blocks (scanned)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_prefix = []
    for i, spec in enumerate(cfg.prefix):
        c = None if caches is None else caches["prefix"][i]
        x, nc, aux = _apply_layer(spec, params["prefix"][i], x, cfg,
                                  positions, c, enc_out)
        new_prefix.append(nc)
        aux_total = aux_total + aux

    def body(carry, scanned):
        from . import sharding as _sh
        xx = _sh.constrain_tokens(carry)
        pp, cc = scanned
        naux = jnp.zeros((), jnp.float32)
        ncs = []
        for i, spec in enumerate(cfg.period):
            ci = None if cc is None else cc[i]
            xx, nci, aux_i = _apply_layer(spec, pp[i], xx, cfg, positions,
                                          ci, enc_out)
            ncs.append(nci)
            naux = naux + aux_i
        return _sh.constrain_tokens(xx), (ncs if cc is not None else None,
                                          naux)

    if remat:
        body = jax.checkpoint(body)

    scanned_caches = None if caches is None else caches["blocks"]
    x, (new_block_caches, auxs) = jax.lax.scan(
        body, x, (params["blocks"], scanned_caches))
    aux_total = aux_total + auxs.sum()
    new_caches = None
    if caches is not None:
        new_caches = {"prefix": new_prefix, "blocks": new_block_caches}
    return x, new_caches, aux_total


def encode(params: PyTree, frames: Array, cfg: ModelConfig) -> Array:
    """Encoder stack for enc-dec models. frames: (B, S_enc, d_model) from the
    modality frontend stub."""
    ct = cfg.cdtype
    x = frames.astype(ct) @ params["enc_in_proj"].astype(ct)
    pos = layers.positions_like(frames[..., 0])
    x = x + _sinusoidal(frames.shape[1], cfg.d_model).astype(ct)[None]

    def body(xx, pp):
        h, _, _ = _apply_layer(LayerSpec(), pp, xx, cfg, pos, None, None,
                               causal=cfg.enc_causal)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return layers.apply_norm(params["enc_final_norm"], x, cfg)


def _sinusoidal(s: int, d: int) -> Array:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _sinusoidal_at(positions: Array, d: int) -> Array:
    """(B, S) positions -> (B, S, d) sinusoidal embeddings."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = positions[..., None].astype(jnp.float32) / jnp.power(
        10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _forward_hidden(params: PyTree, tokens: Array, cfg: ModelConfig,
                    positions: Optional[Array], enc_frames: Optional[Array],
                    remat: bool) -> tuple[Array, Array]:
    """Trunk -> (post-final-norm hidden (B,S,d), aux_loss)."""
    from . import sharding as _sh
    ct = cfg.cdtype
    x = _sh.constrain_tokens(jnp.take(params["embed"], tokens,
                                      axis=0).astype(ct))
    if positions is None:
        positions = layers.positions_like(tokens)
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal_at(positions, cfg.d_model).astype(ct)
    enc_out = None
    if cfg.enc_dec:
        assert enc_frames is not None, "enc-dec model needs encoder frames"
        enc_out = encode(params, enc_frames, cfg)
    x, _, aux = _run_body(params, x, cfg, positions, None, enc_out,
                          remat=remat)
    return layers.apply_norm(params["final_norm"], x, cfg), aux


def forward(params: PyTree, tokens: Array, cfg: ModelConfig,
            positions: Optional[Array] = None,
            enc_frames: Optional[Array] = None,
            remat: bool = False) -> tuple[Array, Array]:
    """Full-sequence forward -> (logits (B,S,V), aux_loss)."""
    h, aux = _forward_hidden(params, tokens, cfg, positions, enc_frames,
                             remat)
    return _project_logits(params, h, cfg), aux


def _project_logits(params: PyTree, x: Array, cfg: ModelConfig) -> Array:
    ct = cfg.cdtype
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(ct).T
    else:
        logits = x @ params["lm_head"].astype(ct)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.logit_softcap)
    return logits


def loss_fn(params: PyTree, tokens: Array, labels: Array, cfg: ModelConfig,
            enc_frames: Optional[Array] = None, remat: bool = True,
            positions: Optional[Array] = None) -> tuple[Array, dict]:
    """Next-token CE (+ MoE aux + optional depth-1 MTP loss)."""
    h, aux = _forward_hidden(params, tokens, cfg, positions, enc_frames,
                             remat)
    logits = _project_logits(params, h, cfg)
    ce = _xent(logits, labels)
    total = ce + 0.01 * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth:
        mtp_loss = _mtp_loss(params, h, tokens, labels, cfg)
        total = total + 0.3 * mtp_loss
        metrics["mtp"] = mtp_loss
    metrics["loss"] = total
    return total, metrics


def _xent(logits: Array, labels: Array) -> Array:
    mask = labels >= 0
    labs = jnp.maximum(labels, 0)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    ll = jnp.take_along_axis(lp, labs[..., None], -1)[..., 0]
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1)


def _mtp_loss(params: PyTree, h: Array, tokens: Array, labels: Array,
              cfg: ModelConfig) -> Array:
    """DeepSeek-V3 depth-1 multi-token prediction: combine the (already
    computed) trunk hidden with the embedding of the next token, run one
    extra block, predict t+2."""
    ct = cfg.cdtype
    x = jnp.take(params["embed"], tokens, axis=0).astype(ct)
    positions = layers.positions_like(tokens)
    # shift: h_t combined with embed(token_{t+1}) predicts label_{t+1} (=tok t+2)
    nxt_emb = jnp.roll(x, -1, axis=1)
    comb = jnp.concatenate([h, nxt_emb], -1) @ params["mtp"]["proj"].astype(ct)
    comb, _, _ = _apply_layer(LayerSpec(), params["mtp"]["block"], comb, cfg,
                              positions, None, None)
    comb = layers.apply_norm(params["mtp"]["norm"], comb, cfg)
    logits = _project_logits(params, comb, cfg)
    mtp_labels = jnp.roll(labels, -1, axis=1).at[:, -1].set(-1)
    return _xent(logits, mtp_labels)


# ============================================================== decode
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0) -> PyTree:
    """Decode cache pytree matching the prefix/period layout."""
    ct = cfg.cdtype

    def one(spec: LayerSpec) -> PyTree:
        c: dict[str, PyTree] = {}
        if spec.kind == "mamba":
            c["mamba"] = ssm.init_mamba_cache(cfg, batch, ct)
        elif cfg.attn_kind == "mla":
            c["attn"] = {
                "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), ct),
                "k_rope": jnp.zeros((batch, max_len, 1, cfg.qk_rope_dim), ct),
                "len": jnp.zeros((), jnp.int32),
            }
        else:
            t = min(max_len, cfg.window) if cfg.window else max_len
            c["attn"] = {
                "k": jnp.zeros((batch, t, cfg.n_kv, cfg.d_head), ct),
                "v": jnp.zeros((batch, t, cfg.n_kv, cfg.d_head), ct),
                "len": jnp.zeros((), jnp.int32),
            }
        if spec.cross_attn:
            c["xattn"] = {
                "k": jnp.zeros((batch, enc_len, cfg.n_kv, cfg.d_head), ct),
                "v": jnp.zeros((batch, enc_len, cfg.n_kv, cfg.d_head), ct),
            }
        return c

    caches = {
        "prefix": [one(s) for s in cfg.prefix],
        "blocks": jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[[one(s) for s in cfg.period] for _ in range(cfg.n_periods)]),
        "step": jnp.zeros((), jnp.int32),
    }
    return caches


def fill_cross_caches(params: PyTree, caches: PyTree, enc_out: Array,
                      cfg: ModelConfig) -> PyTree:
    """Precompute cross-attention K/V from the encoder output into the decode
    cache (keeps the cache pytree structure scan-stable)."""
    ct = cfg.cdtype
    enc = enc_out.astype(ct)

    def kv(wk, wv):
        # wk/wv may carry a leading stacked period axis.
        eq = "btd,dhk->bthk" if wk.ndim == 3 else "btd,ldhk->lbthk"
        return (jnp.einsum(eq, enc, wk.astype(ct)),
                jnp.einsum(eq, enc, wv.astype(ct)))

    for i, spec in enumerate(cfg.prefix):
        if spec.cross_attn:
            p = params["prefix"][i]["xattn"]
            k, v = kv(p["wk"], p["wv"])
            caches["prefix"][i]["xattn"] = {"k": k, "v": v}
    for i, spec in enumerate(cfg.period):
        if spec.cross_attn:
            p = params["blocks"][i]["xattn"]
            k, v = kv(p["wk"], p["wv"])
            caches["blocks"][i]["xattn"] = {"k": k, "v": v}
    return caches


def decode_step(params: PyTree, token: Array, caches: PyTree,
                cfg: ModelConfig, enc_out: Optional[Array] = None
                ) -> tuple[Array, PyTree]:
    """One decode step. token (B, 1) int32 -> (logits (B, 1, V), new caches).

    Cross-attention K/V must already be in the cache (fill_cross_caches);
    enc_out is accepted for API symmetry but unused when caches are filled.
    """
    del enc_out
    ct = cfg.cdtype
    x = jnp.take(params["embed"], token, axis=0).astype(ct)
    positions = jnp.broadcast_to(caches["step"], (token.shape[0], 1)).astype(jnp.int32)
    if cfg.pos_embed == "sinusoidal":
        x = x + _sinusoidal_at(positions, cfg.d_model).astype(ct)
    inner = {"prefix": caches["prefix"], "blocks": caches["blocks"]}
    x, new_inner, _ = _run_body(params, x, cfg, positions, inner, None)
    x = layers.apply_norm(params["final_norm"], x, cfg)
    logits = _project_logits(params, x, cfg)
    new_caches = dict(new_inner)
    new_caches["step"] = caches["step"] + 1
    return logits, new_caches


def prefill(params: PyTree, tokens: Array, cfg: ModelConfig, max_len: int,
            enc_frames: Optional[Array] = None
            ) -> tuple[Array, PyTree, Optional[Array]]:
    """Run the prompt through the decoder step-by-step to build a cache.

    (A fused flash-prefill that writes the cache in one pass is the
    production path for TPU; the step loop keeps CPU smoke tests simple and
    exercises exactly the serve_step that the dry-run lowers.)
    """
    b, s = tokens.shape
    enc_out = encode(params, enc_frames, cfg) if cfg.enc_dec else None
    caches = init_cache(cfg, b, max_len,
                        enc_len=0 if enc_frames is None else enc_frames.shape[1])
    if enc_out is not None:
        caches = fill_cross_caches(params, caches, enc_out, cfg)

    def body(carry, t):
        cc = carry
        logits, cc = decode_step(params, jax.lax.dynamic_slice_in_dim(
            tokens, t, 1, axis=1), cc, cfg)
        return cc, logits[:, 0]

    caches, all_logits = jax.lax.scan(body, caches, jnp.arange(s))
    return jnp.moveaxis(all_logits, 0, 1), caches, enc_out
