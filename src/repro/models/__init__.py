from .config import ModelConfig
from . import model, layers, ssm, moe, sharding

__all__ = ["ModelConfig", "model", "layers", "ssm", "moe", "sharding"]
