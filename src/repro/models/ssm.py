"""Mamba2 block via the SSD (state-space duality) chunked algorithm
(Dao & Gu, arXiv:2405.21060).

Training/prefill uses the chunked form: quadratic attention-like matmuls
within chunks (MXU-friendly) + a sequential inter-chunk state recurrence
(lax.scan over S/chunk steps). Decode carries the (H, P, N) recurrent state —
O(1) per token, which is what qualifies SSM/hybrid archs for the long_500k
shape.

Shapes follow the reference implementation: d_inner = expand*d_model,
H = d_inner/head_dim heads, G state groups (B/C shared across H/G heads),
N = ssm_state.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig

Array = jax.Array
PyTree = Any


def init_mamba(key: Array, cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    din, ns, nh, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
    conv_dim = din + 2 * g * ns
    ks = jax.random.split(key, 4)
    scale = 0.02
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * din + 2 * g * ns + nh),
                                      jnp.float32) * scale).astype(cfg.pdtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                     jnp.float32) * scale).astype(cfg.pdtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.pdtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((din,), cfg.pdtype),
        "out_proj": (jax.random.normal(ks[2], (din, d),
                                       jnp.float32) * scale).astype(cfg.pdtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: Array):
    din, ns, nh, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din: 2 * din + 2 * g * ns]
    dt = zxbcdt[..., 2 * din + 2 * g * ns:]
    return z, xBC, dt


def _gated_norm(x: Array, z: Array, scale: Array) -> Array:
    xf = (x * jax.nn.silu(z)).astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _ssd_chunked(x: Array, dt: Array, A: Array, B: Array, C: Array,
                 D: Array, chunk: int,
                 h0: Optional[Array] = None) -> tuple[Array, Array]:
    """SSD scan. x (b,s,h,p), dt (b,s,h) >0, A (h,)<0, B/C (b,s,g,n).

    Returns (y (b,s,h,p), final state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    dA = dtc * A[None, None, None, :]                 # (b,nc,c,h) negative
    dA_cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumsum

    # intra-chunk (attention-like): L[i,j] = exp(dA_cum[i]-dA_cum[j]) for j<=i
    # NB: mask BEFORE exp — future entries have seg >> 0 and exp would
    # overflow; where() after exp leaks NaN into the backward pass.
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]   # (b,nc,c,c,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    seg = jnp.where(mask[None, None, :, :, None], seg, -1e30)
    L = jnp.exp(seg)
    Bh = jnp.repeat(Bc, rep, axis=3)                  # (b,nc,c,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)
    scores = jnp.einsum("bzchn,bzkhn->bzckh", Ch, Bh)  # (b,nc,c,c,h)
    att = scores * L
    xdt = xc * dtc[..., None]                          # (b,nc,c,h,p)
    y_diag = jnp.einsum("bzckh,bzkhp->bzchp", att, xdt)

    # chunk summary states: S_z = sum_j exp(dA_end - dA_cum[j]) B_j (dt_j x_j)
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)       # (b,nc,c,h)
    S = jnp.einsum("bzchn,bzchp->bzhnp",
                   (Bh * decay_to_end[..., None]).astype(jnp.float32),
                   xdt.astype(jnp.float32))                     # per-chunk, f32

    # inter-chunk recurrence over nc (sequential scan, f32 state)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                  # (b,nc,h) f32

    def body(carry, inp):
        s_z, d_z = inp                 # (b,h,n,p), (b,h)
        new = carry * d_z[..., None, None] + s_z
        return new, carry              # emit state BEFORE this chunk

    init = (jnp.zeros((b, h, n, p), jnp.float32) if h0 is None
            else h0.transpose(0, 1, 3, 2).astype(jnp.float32))  # (b,h,n,p)
    final, prev_states = jax.lax.scan(
        body, init,
        (S.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)          # (b,nc,h,n,p)

    # inter-chunk contribution: y_off[i] = C_i · (decay_from_start[i] * prev)
    decay_from_start = jnp.exp(dA_cum)                          # (b,nc,c,h)
    y_off = jnp.einsum("bzchn,bznhp->bzchp",
                       (Ch * decay_from_start[..., None]).astype(jnp.float32),
                       prev_states.transpose(0, 1, 3, 2, 4)).astype(x.dtype)

    y = (y_diag + y_off).reshape(b, s, h, p) + x * D[None, None, :, None]
    return y, final.transpose(0, 1, 3, 2)                       # (b,h,p,n)


def mamba_forward(p: PyTree, x: Array, cfg: ModelConfig,
                  cache: Optional[PyTree] = None
                  ) -> tuple[Array, Optional[PyTree]]:
    """Full-sequence forward (cache=None) or single-token decode step.

    Decode cache: {"conv": (B, K-1, conv_dim), "h": (B, H, P, N)}.
    """
    b, s, d = x.shape
    ct = cfg.cdtype
    din, ns, nh, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
    hd = cfg.ssm_head_dim
    zxbcdt = x.astype(ct) @ p["in_proj"].astype(ct)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    A = -jnp.exp(p["A_log"])                                    # (h,) < 0
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,s,h)

    if cache is None:
        # depthwise causal conv over the sequence
        k = cfg.ssm_conv
        pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
        conv = sum(pad[:, i: i + s] * p["conv_w"].astype(ct)[i]
                   for i in range(k))
        xBC_c = jax.nn.silu(conv + p["conv_b"].astype(ct))
        xs = xBC_c[..., :din].reshape(b, s, nh, hd)
        B = xBC_c[..., din: din + g * ns].reshape(b, s, g, ns)
        C = xBC_c[..., din + g * ns:].reshape(b, s, g, ns)
        pad_s = (-s) % cfg.ssm_chunk
        if pad_s:
            xs = jnp.pad(xs, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, 0)))
            B = jnp.pad(B, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
            C = jnp.pad(C, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        y, hfinal = _ssd_chunked(xs, dt, A, B, C, p["D"], cfg.ssm_chunk)
        y = y[:, :s].reshape(b, s, din)
        y = _gated_norm(y, z, p["norm_scale"]).astype(ct)
        out = y @ p["out_proj"].astype(ct)
        new_cache = {
            "conv": pad[:, -(k - 1):] if k > 1 else jnp.zeros((b, 0, xBC.shape[-1]), ct),
            "h": hfinal.astype(ct),
        }
        return out, new_cache

    # ---- decode: s == 1
    k = cfg.ssm_conv
    conv_in = jnp.concatenate([cache["conv"].astype(ct), xBC], axis=1)  # (b,k,cd)
    conv = (conv_in * p["conv_w"].astype(ct)[None]).sum(1, keepdims=True)
    xBC_c = jax.nn.silu(conv + p["conv_b"].astype(ct))                  # (b,1,cd)
    xs = xBC_c[..., :din].reshape(b, nh, hd)
    B = xBC_c[..., din: din + g * ns].reshape(b, g, ns)
    C = xBC_c[..., din + g * ns:].reshape(b, g, ns)
    rep = nh // g
    Bh = jnp.repeat(B, rep, axis=1)                                     # (b,h,n)
    Ch = jnp.repeat(C, rep, axis=1)
    dt1 = dt[:, 0]                                                      # (b,h)
    dA = jnp.exp(dt1 * A[None, :])                                      # (b,h)
    hprev = cache["h"].astype(jnp.float32)                              # (b,h,p,n)
    hnew = (hprev * dA[..., None, None]
            + jnp.einsum("bhn,bhp->bhpn", Bh.astype(jnp.float32),
                         (xs * dt1[..., None]).astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", hnew, Ch.astype(jnp.float32))
    y = y.astype(ct) + xs * p["D"].astype(ct)[None, :, None]
    y = y.reshape(b, 1, din)
    y = _gated_norm(y, z, p["norm_scale"]).astype(ct)
    out = y @ p["out_proj"].astype(ct)
    new_cache = {"conv": conv_in[:, 1:], "h": hnew.astype(cache["h"].dtype)}
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> PyTree:
    din, ns, nh, g = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_groups
    conv_dim = din + 2 * g * ns
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, nh, cfg.ssm_head_dim, ns), dtype),
    }
