"""Unified model configuration covering the full assigned architecture pool.

One dataclass describes dense / MoE / SSM / hybrid / enc-dec / VLM-backbone
transformers; per-layer heterogeneity (jamba's 1:7 mamba:attn interleave,
deepseek-v3's dense-prefix) is expressed with a repeating ``period`` of layer
specs plus an unrolled ``prefix``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str = "attn"          # attn | mamba
    moe: bool = False           # MoE MLP instead of dense MLP
    cross_attn: bool = False    # enc-dec decoder blocks


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0                      # 0 -> d_model // n_heads

    # --- layer pattern -----------------------------------------------------
    period: Tuple[LayerSpec, ...] = (LayerSpec(),)
    prefix: Tuple[LayerSpec, ...] = ()   # unrolled leading layers (dsv3 dense)

    # --- attention ---------------------------------------------------------
    attn_kind: str = "gqa"               # gqa | mla
    attn_pad_heads: int = 0              # physical head padding for TP
    #   (sharding-layout decision, NOT an architecture change: padded query
    #   heads are hard-masked to zero before the output projection, so the
    #   function computed — and every gradient — is bit-identical to the
    #   unpadded model; see EXPERIMENTS.md §Perf/minitron)
    window: int = 0                      # sliding-window size (0 = full)
    causal: bool = True
    rope: str = "rope"                   # rope | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)   # t/h/w halves

    # --- MLA (deepseek-v3) ---------------------------------------------------
    q_lora_rank: int = 0                 # 0 -> dense q projection
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- MLP / MoE -----------------------------------------------------------
    mlp_kind: str = "swiglu"             # swiglu | mlp (non-gated)
    act: str = "silu"                    # silu | gelu | relu2
    n_experts: int = 0
    top_k: int = 2
    n_shared_experts: int = 0
    d_ff_expert: int = 0                 # 0 -> d_ff
    d_ff_dense: int = 0                  # dense-prefix layers (dsv3: 18432)
    capacity_factor: float = 1.25
    router_noise: float = 0.0

    # --- Mamba2 / SSD ----------------------------------------------------------
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv: int = 4
    ssm_groups: int = 1

    # --- enc-dec ---------------------------------------------------------------
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_causal: bool = False

    # --- embeddings / norms ------------------------------------------------------
    norm: str = "rmsnorm"                # rmsnorm | layernorm | nonparam_ln
    tie_embeddings: bool = False
    pos_embed: str = "none"              # none | learned  (whisper decoder)
    max_pos: int = 0                     # learned pos table size
    logit_softcap: float = 0.0           # grok-style tanh soft-capping

    # --- modality frontend stub ---------------------------------------------------
    frontend: str = "none"               # none | audio_stub | vision_stub

    # --- numerics ------------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # --- MTP (deepseek-v3 multi-token prediction, optional aux head) -----------------
    mtp_depth: int = 0

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))
        body = self.n_layers - len(self.prefix)
        assert body >= 0 and body % len(self.period) == 0, (
            f"{self.name}: n_layers={self.n_layers} incompatible with "
            f"prefix={len(self.prefix)} + period={len(self.period)}")

    # ------------------------------------------------------------------ helpers
    @property
    def n_periods(self) -> int:
        return (self.n_layers - len(self.prefix)) // len(self.period)

    @property
    def d_inner(self) -> int:          # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def ff_expert(self) -> int:
        return self.d_ff_expert or self.d_ff

    @property
    def ff_dense(self) -> int:
        return self.d_ff_dense or self.d_ff

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / SWA)."""
        kinds = {s.kind for s in self.prefix + self.period}
        if kinds == {"mamba"}:
            return True
        if "mamba" in kinds:
            return True                   # hybrid: attn layers still cache S
        return self.window > 0            # sliding window attention

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        return self.prefix + self.period * self.n_periods

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline N."""
        d, dh = self.d_model, self.d_head
        total = self.vocab * d                      # embed
        if not self.tie_embeddings:
            total += d * self.vocab                 # lm head
        if self.pos_embed == "learned" and self.max_pos:
            total += self.max_pos * d

        def attn_params() -> int:
            if self.attn_kind == "mla":
                qk = self.qk_nope_dim + self.qk_rope_dim
                p = 0
                if self.q_lora_rank:
                    p += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qk
                else:
                    p += d * self.n_heads * qk
                p += d * (self.kv_lora_rank + self.qk_rope_dim)
                p += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                p += self.n_heads * self.v_head_dim * d
                return p
            return d * self.n_heads * dh + 2 * d * self.n_kv * dh + self.n_heads * dh * d

        def mlp_params(ff: int) -> int:
            mults = 3 if self.mlp_kind == "swiglu" else 2
            return mults * d * ff

        def mamba_params() -> int:
            din, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            conv_dim = din + 2 * self.ssm_groups * ns
            p = d * (2 * din + 2 * self.ssm_groups * ns + nh)   # in_proj
            p += conv_dim * self.ssm_conv                        # conv
            p += nh * 2 + nh                                     # A, D, dt_bias
            p += din * d                                          # out_proj
            return p

        for i, spec in enumerate(self.layer_specs()):
            is_prefix = i < len(self.prefix)
            if spec.kind == "mamba":
                total += mamba_params()
            else:
                total += attn_params()
                if spec.cross_attn:
                    total += attn_params()
            if spec.moe:
                e = self.n_experts + self.n_shared_experts
                total += e * mlp_params(self.ff_expert) + d * self.n_experts
            else:
                total += mlp_params(self.ff_dense if is_prefix else self.d_ff)
        if self.enc_dec:
            for _ in range(self.n_enc_layers):
                total += attn_params() + mlp_params(self.d_ff)
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE top-k), for MODEL_FLOPS = 6·N_active·D."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        mults = 3 if self.mlp_kind == "swiglu" else 2
        per_expert = mults * d * self.ff_expert
        inactive = (self.n_experts - self.top_k) * per_expert
        n_moe_layers = sum(s.moe for s in self.layer_specs())
        return self.param_count() - n_moe_layers * inactive
