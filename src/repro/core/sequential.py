"""Sequential Ant System in pure NumPy.

Mirrors the structure of Stützle's ANSI-C ACOTSP code (the paper's CPU
baseline): per-ant sequential roulette-wheel construction with precomputed
choice_info, then evaporation + per-edge deposit. Used as (a) the wall-clock
baseline for the Fig. 4/5 speed-up reproductions and (b) the solution-quality
oracle for claim C6.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class SequentialAS:
    def __init__(self, dist: np.ndarray, alpha: float = 1.0, beta: float = 2.0,
                 rho: float = 0.5, m: Optional[int] = None, seed: int = 0,
                 nn_k: int = 0):
        self.dist = np.asarray(dist, np.float64)
        self.n = self.dist.shape[0]
        self.m = m if m is not None else self.n
        self.alpha, self.beta, self.rho = alpha, beta, rho
        self.rng = np.random.RandomState(seed)
        eps = 1e-10
        self.eta = 1.0 / np.maximum(self.dist, eps)
        # tau0 = m / C_nn
        c_nn = self._nn_tour_length()
        self.tau = np.full((self.n, self.n), self.m / c_nn)
        self.best_tour = None
        self.best_len = np.inf
        self.nn_k = nn_k
        if nn_k:
            d = self.dist + np.eye(self.n) * 1e18
            self.nn = np.argsort(d, axis=1)[:, :nn_k]

    def _nn_tour_length(self) -> float:
        visited = np.zeros(self.n, bool)
        cur, total = 0, 0.0
        visited[0] = True
        for _ in range(self.n - 1):
            d = np.where(visited, np.inf, self.dist[cur])
            nxt = int(np.argmin(d))
            total += self.dist[cur, nxt]
            visited[nxt] = True
            cur = nxt
        return total + self.dist[cur, 0]

    def construct(self) -> tuple[np.ndarray, np.ndarray]:
        choice = (self.tau ** self.alpha) * (self.eta ** self.beta)
        tours = np.empty((self.m, self.n), np.int32)
        lengths = np.empty(self.m)
        for k in range(self.m):
            visited = np.zeros(self.n, bool)
            cur = self.rng.randint(self.n)
            tours[k, 0] = cur
            visited[cur] = True
            for s in range(1, self.n):
                if self.nn_k:
                    cand = self.nn[cur]
                    w = choice[cur, cand] * (~visited[cand])
                    tot = w.sum()
                    if tot > 0:
                        r = self.rng.uniform(0, tot)
                        nxt = int(cand[np.searchsorted(np.cumsum(w), r)])
                    else:
                        full = choice[cur] * (~visited)
                        nxt = int(np.argmax(full))
                else:
                    w = choice[cur] * (~visited)
                    r = self.rng.uniform(0, w.sum())
                    nxt = int(np.searchsorted(np.cumsum(w), r))
                    nxt = min(nxt, self.n - 1)
                tours[k, s] = nxt
                visited[nxt] = True
                cur = nxt
            lengths[k] = self.dist[tours[k], np.roll(tours[k], -1)].sum()
        return tours, lengths

    def update_pheromone(self, tours: np.ndarray, lengths: np.ndarray) -> None:
        self.tau *= (1.0 - self.rho)
        for k in range(tours.shape[0]):
            w = 1.0 / lengths[k]
            t = tours[k]
            nxt = np.roll(t, -1)
            self.tau[t, nxt] += w
            self.tau[nxt, t] += w

    def iterate(self) -> float:
        tours, lengths = self.construct()
        i = int(np.argmin(lengths))
        if lengths[i] < self.best_len:
            self.best_len = float(lengths[i])
            self.best_tour = tours[i].copy()
        self.update_pheromone(tours, lengths)
        return float(lengths[i])

    def run(self, iterations: int) -> float:
        for _ in range(iterations):
            self.iterate()
        return self.best_len
