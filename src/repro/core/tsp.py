"""TSP problem substrate: instances, distance matrices, nearest-neighbour lists.

TSPLIB conventions are followed for distance rounding (EUC_2D uses
nint(sqrt), ATT uses the pseudo-Euclidean ceiling rule) so tour lengths are
comparable with published optima when real instances are loaded from files.
Synthetic generators (uniform-random and circle, the latter with a known
optimal tour) are provided for offline benchmarking at the paper's problem
sizes (48 .. 2392 cities).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TSPInstance:
    """A (symmetric) TSP instance.

    coords: (n, 2) float64 city coordinates, or None if dist_matrix given.
    edge_weight_type: TSPLIB distance function name.
    """

    name: str
    coords: Optional[np.ndarray] = None
    edge_weight_type: str = "EUC_2D"
    dist_matrix: Optional[np.ndarray] = None
    known_optimum: Optional[float] = None

    @property
    def n(self) -> int:
        if self.coords is not None:
            return int(self.coords.shape[0])
        assert self.dist_matrix is not None
        return int(self.dist_matrix.shape[0])

    def distances(self) -> np.ndarray:
        """Dense (n, n) float32 distance matrix with TSPLIB rounding."""
        if self.dist_matrix is not None:
            return np.asarray(self.dist_matrix, dtype=np.float32)
        assert self.coords is not None
        xy = self.coords.astype(np.float64)
        diff = xy[:, None, :] - xy[None, :, :]
        if self.edge_weight_type == "EUC_2D":
            d = np.rint(np.sqrt((diff**2).sum(-1)))
        elif self.edge_weight_type == "CEIL_2D":
            d = np.ceil(np.sqrt((diff**2).sum(-1)))
        elif self.edge_weight_type == "ATT":
            rij = np.sqrt((diff**2).sum(-1) / 10.0)
            tij = np.rint(rij)
            d = np.where(tij < rij, tij + 1.0, tij)
        elif self.edge_weight_type == "RAW":  # no rounding (synthetic)
            d = np.sqrt((diff**2).sum(-1))
        else:
            raise ValueError(f"unsupported edge_weight_type {self.edge_weight_type}")
        np.fill_diagonal(d, 0.0)
        return d.astype(np.float32)


def random_instance(n: int, seed: int = 0, box: float = 1000.0) -> TSPInstance:
    """Uniform-random Euclidean instance (synthetic stand-in for TSPLIB)."""
    rng = np.random.RandomState(seed)
    coords = rng.uniform(0.0, box, size=(n, 2))
    return TSPInstance(name=f"rand{n}", coords=coords, edge_weight_type="RAW")


def circle_instance(n: int, radius: float = 1000.0, seed: int = 0) -> TSPInstance:
    """Cities on a circle: the optimal tour is the angular order.

    known_optimum = perimeter of the polygon through sorted angles. Used for
    honest solution-quality validation without shipping TSPLIB data files.
    """
    rng = np.random.RandomState(seed)
    theta = np.sort(rng.uniform(0.0, 2.0 * math.pi, size=n))
    coords = radius * np.stack([np.cos(theta), np.sin(theta)], axis=-1)
    closed = np.concatenate([coords, coords[:1]], axis=0)
    opt = float(np.sqrt(((closed[1:] - closed[:-1]) ** 2).sum(-1)).sum())
    return TSPInstance(
        name=f"circle{n}", coords=coords, edge_weight_type="RAW", known_optimum=opt
    )


def grid_instance(side: int) -> TSPInstance:
    """side x side unit grid; optimum = side*side for even side (boustrophedon)."""
    xs, ys = np.meshgrid(np.arange(side), np.arange(side))
    coords = np.stack([xs.ravel(), ys.ravel()], axis=-1).astype(np.float64)
    opt = float(side * side) if side % 2 == 0 else None
    return TSPInstance(
        name=f"grid{side}x{side}", coords=coords, edge_weight_type="RAW",
        known_optimum=opt,
    )


SUPPORTED_EDGE_WEIGHT_TYPES = ("EUC_2D", "CEIL_2D", "ATT")


def parse_tsplib(text: str, name: str = "tsplib") -> TSPInstance:
    """Minimal TSPLIB .tsp parser (NODE_COORD_SECTION, EUC_2D/ATT/CEIL_2D)."""
    ewt = "EUC_2D"
    m = re.search(r"EDGE_WEIGHT_TYPE\s*:\s*(\w+)", text)
    if m:
        ewt = m.group(1)
    if ewt not in SUPPORTED_EDGE_WEIGHT_TYPES:
        raise ValueError(
            f"unsupported EDGE_WEIGHT_TYPE {ewt!r}; "
            f"supported: {', '.join(SUPPORTED_EDGE_WEIGHT_TYPES)}")
    nm = re.search(r"NAME\s*:\s*(\S+)", text)
    if nm:
        name = nm.group(1)
    lines = text.splitlines()
    coords = []
    in_sec = False
    for ln in lines:
        s = ln.strip()
        if s.startswith("NODE_COORD_SECTION"):
            in_sec = True
            continue
        if in_sec:
            if s == "EOF" or not s:
                break
            parts = s.split()
            coords.append((float(parts[1]), float(parts[2])))
    if not coords:
        raise ValueError("no NODE_COORD_SECTION found")
    return TSPInstance(name=name, coords=np.asarray(coords), edge_weight_type=ewt)


def pad_instance(instance: TSPInstance, n_pad: int) -> TSPInstance:
    """Pad an instance to ``n_pad`` cities with masked phantom cities.

    Phantom cities (indices >= instance.n) sit at infinite distance from
    every real city and from each other (diagonal stays 0), so their
    heuristic eta = 1/d is exactly 0 and no masked code path can ever
    prefer them.  The solver engine (solver/batch.py) buckets instances by
    padded size so one vmapped program serves many heterogeneous instances;
    DESIGN.md §8 records the masking invariants.
    """
    n = instance.n
    if n_pad < n:
        raise ValueError(f"n_pad={n_pad} < instance size {n}")
    if n_pad == n:
        return instance
    d = np.full((n_pad, n_pad), np.inf, dtype=np.float32)
    d[:n, :n] = instance.distances()
    np.fill_diagonal(d, 0.0)
    return TSPInstance(name=instance.name, dist_matrix=d,
                       edge_weight_type=instance.edge_weight_type,
                       known_optimum=instance.known_optimum)


def nn_lists(dist: Array, k: int) -> Array:
    """(n, k) int32 nearest-neighbour lists, self excluded (paper §II, nn=15..40)."""
    n = dist.shape[0]
    d = dist + jnp.eye(n, dtype=dist.dtype) * jnp.finfo(dist.dtype).max
    _, idx = jax.lax.top_k(-d, k)
    return idx.astype(jnp.int32)


def tour_length(dist: Array, tour: Array, n_actual: Optional[Array] = None) -> Array:
    """Closed-tour length; tour (..., n) int32 city permutation.

    With ``n_actual`` (a traced scalar, per-instance under vmap) the tour is
    treated as a padded tour whose real cities occupy positions
    ``0..n_actual-1``: the closing edge runs from position n_actual-1 back to
    position 0 and phantom-tail edges contribute 0 (masked with ``where``,
    never multiplied — phantom distances are inf).
    """
    nxt = jnp.roll(tour, -1, axis=-1)
    if n_actual is None:
        return jnp.take_along_axis(
            dist[tour], nxt[..., None], axis=-1
        )[..., 0].sum(-1)
    idx = jnp.arange(tour.shape[-1], dtype=jnp.int32)
    nxt = jnp.where(idx == n_actual - 1, tour[..., :1], nxt)
    d = jnp.take_along_axis(dist[tour], nxt[..., None], axis=-1)[..., 0]
    return jnp.where(idx < n_actual, d, 0.0).sum(-1)


def heuristic_matrix(dist: Array) -> Array:
    """eta = 1/d with safe diagonal (paper eq. 1)."""
    eps = jnp.asarray(1e-10, dist.dtype)
    return 1.0 / jnp.maximum(dist, eps)


def is_valid_tour(tour: np.ndarray) -> bool:
    tour = np.asarray(tour)
    n = tour.shape[-1]
    return bool((np.sort(tour, axis=-1) == np.arange(n)).all())


def nearest_neighbour_tour(dist: np.ndarray, start: int = 0) -> tuple[np.ndarray, float]:
    """Greedy NN heuristic tour — used for tau0 initialisation (Dorigo &
    Stützle: tau0 = m / C_nn) and as a quality yardstick."""
    dist = np.asarray(dist)
    n = dist.shape[0]
    visited = np.zeros(n, dtype=bool)
    tour = np.empty(n, dtype=np.int32)
    cur = start
    tour[0] = cur
    visited[cur] = True
    for i in range(1, n):
        d = np.where(visited, np.inf, dist[cur])
        cur = int(np.argmin(d))
        tour[i] = cur
        visited[cur] = True
    length = float(dist[tour, np.roll(tour, -1)].sum())
    return tour, length
