"""TSP problem substrate: instances, distance matrices, nearest-neighbour lists.

TSPLIB conventions are followed for distance rounding (EUC_2D uses
nint(sqrt), ATT uses the pseudo-Euclidean ceiling rule) so tour lengths are
comparable with published optima when real instances are loaded from files.
Synthetic generators (uniform-random and circle, the latter with a known
optimal tour) are provided for offline benchmarking at the paper's problem
sizes (48 .. 2392 cities).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TSPInstance:
    """A (symmetric) TSP instance.

    coords: (n, 2) float64 city coordinates, or None if dist_matrix given.
    edge_weight_type: TSPLIB distance function name.
    """

    name: str
    coords: Optional[np.ndarray] = None
    edge_weight_type: str = "EUC_2D"
    dist_matrix: Optional[np.ndarray] = None
    known_optimum: Optional[float] = None

    @property
    def n(self) -> int:
        if self.coords is not None:
            return int(self.coords.shape[0])
        assert self.dist_matrix is not None
        return int(self.dist_matrix.shape[0])

    def distances(self) -> np.ndarray:
        """Dense (n, n) float32 distance matrix with TSPLIB rounding."""
        if self.dist_matrix is not None:
            return np.asarray(self.dist_matrix, dtype=np.float32)
        assert self.coords is not None
        xy = self.coords.astype(np.float64)
        d = pairwise_distances(xy, xy, self.edge_weight_type)
        np.fill_diagonal(d, 0.0)
        return d.astype(np.float32)


def pairwise_distances(xy_a: np.ndarray, xy_b: np.ndarray,
                       edge_weight_type: str) -> np.ndarray:
    """(a, b) float64 TSPLIB-rounded distances between two coordinate sets.

    The single source of the rounding rules: ``TSPInstance.distances`` runs
    the full (n, n) matrix through it, and the sparse candidate builder
    (repro.sparse.store) runs row *chunks* through it — the same float64
    arithmetic followed by the same float32 cast downstream, so a candidate
    edge's stored distance is bitwise the dense matrix entry.
    """
    xy_a = np.asarray(xy_a, np.float64)
    xy_b = np.asarray(xy_b, np.float64)
    diff = xy_a[:, None, :] - xy_b[None, :, :]
    if edge_weight_type == "EUC_2D":
        return np.rint(np.sqrt((diff**2).sum(-1)))
    if edge_weight_type == "CEIL_2D":
        return np.ceil(np.sqrt((diff**2).sum(-1)))
    if edge_weight_type == "ATT":
        rij = np.sqrt((diff**2).sum(-1) / 10.0)
        tij = np.rint(rij)
        return np.where(tij < rij, tij + 1.0, tij)
    if edge_weight_type == "RAW":  # no rounding (synthetic)
        return np.sqrt((diff**2).sum(-1))
    raise ValueError(f"unsupported edge_weight_type {edge_weight_type}")


def random_instance(n: int, seed: int = 0, box: float = 1000.0) -> TSPInstance:
    """Uniform-random Euclidean instance (synthetic stand-in for TSPLIB)."""
    rng = np.random.RandomState(seed)
    coords = rng.uniform(0.0, box, size=(n, 2))
    return TSPInstance(name=f"rand{n}", coords=coords, edge_weight_type="RAW")


def circle_instance(n: int, radius: float = 1000.0, seed: int = 0) -> TSPInstance:
    """Cities on a circle: the optimal tour is the angular order.

    known_optimum = perimeter of the polygon through sorted angles. Used for
    honest solution-quality validation without shipping TSPLIB data files.
    """
    rng = np.random.RandomState(seed)
    theta = np.sort(rng.uniform(0.0, 2.0 * math.pi, size=n))
    coords = radius * np.stack([np.cos(theta), np.sin(theta)], axis=-1)
    closed = np.concatenate([coords, coords[:1]], axis=0)
    opt = float(np.sqrt(((closed[1:] - closed[:-1]) ** 2).sum(-1)).sum())
    return TSPInstance(
        name=f"circle{n}", coords=coords, edge_weight_type="RAW", known_optimum=opt
    )


def grid_instance(side: int) -> TSPInstance:
    """side x side unit grid; optimum = side*side for even side (boustrophedon)."""
    xs, ys = np.meshgrid(np.arange(side), np.arange(side))
    coords = np.stack([xs.ravel(), ys.ravel()], axis=-1).astype(np.float64)
    opt = float(side * side) if side % 2 == 0 else None
    return TSPInstance(
        name=f"grid{side}x{side}", coords=coords, edge_weight_type="RAW",
        known_optimum=opt,
    )


SUPPORTED_EDGE_WEIGHT_TYPES = ("EUC_2D", "CEIL_2D", "ATT", "EXPLICIT")
SUPPORTED_EDGE_WEIGHT_FORMATS = ("FULL_MATRIX", "UPPER_ROW", "LOWER_ROW",
                                 "UPPER_DIAG_ROW", "LOWER_DIAG_ROW")

_SECTION_KEYWORDS = ("NODE_COORD_SECTION", "EDGE_WEIGHT_SECTION",
                     "DISPLAY_DATA_SECTION", "FIXED_EDGES_SECTION",
                     "TOUR_SECTION", "EOF")


def _explicit_matrix(values: list[float], n: int, fmt: str) -> np.ndarray:
    """Assemble a symmetric (n, n) matrix from an EDGE_WEIGHT_SECTION stream."""
    need = {
        "FULL_MATRIX": n * n,
        "UPPER_ROW": n * (n - 1) // 2,
        "LOWER_ROW": n * (n - 1) // 2,
        "UPPER_DIAG_ROW": n * (n + 1) // 2,
        "LOWER_DIAG_ROW": n * (n + 1) // 2,
    }[fmt]
    if len(values) < need:
        raise ValueError(
            f"EDGE_WEIGHT_SECTION has {len(values)} values; "
            f"{fmt} with DIMENSION {n} needs {need}")
    vals = np.asarray(values[:need], dtype=np.float64)
    d = np.zeros((n, n), dtype=np.float64)
    if fmt == "FULL_MATRIX":
        d = vals.reshape(n, n)
    else:
        diag = fmt.endswith("DIAG_ROW")
        upper = fmt.startswith("UPPER")
        iu = (np.triu_indices(n, 0 if diag else 1) if upper
              else np.tril_indices(n, 0 if diag else -1))
        d[iu] = vals
        d = d + d.T - np.diag(np.diag(d))
    np.fill_diagonal(d, 0.0)
    return d.astype(np.float32)


def parse_tsplib(text: str, name: str = "tsplib") -> TSPInstance:
    """TSPLIB .tsp parser.

    Supported: NODE_COORD_SECTION instances with EUC_2D / ATT / CEIL_2D
    rounding (the paper's benchmark families, pr1002/pr2392 included) and
    EXPLICIT distance matrices (EDGE_WEIGHT_SECTION in FULL_MATRIX /
    UPPER_ROW / LOWER_ROW / UPPER_DIAG_ROW / LOWER_DIAG_ROW formats).
    DISPLAY_DATA_SECTION blocks (display-only coordinates some EXPLICIT
    instances carry) are skipped.  Anything else is rejected eagerly with
    the exact field that is unsupported, not deep inside a solve.
    """
    ewt = "EUC_2D"
    m = re.search(r"EDGE_WEIGHT_TYPE\s*:\s*([\w_]+)", text)
    if m:
        ewt = m.group(1)
    if ewt not in SUPPORTED_EDGE_WEIGHT_TYPES:
        raise ValueError(
            f"unsupported EDGE_WEIGHT_TYPE {ewt!r}; "
            f"supported: {', '.join(SUPPORTED_EDGE_WEIGHT_TYPES)}")
    nm = re.search(r"NAME\s*:\s*(\S+)", text)
    if nm:
        name = nm.group(1)
    fmt = None
    fm = re.search(r"EDGE_WEIGHT_FORMAT\s*:\s*([\w_]+)", text)
    if fm:
        fmt = fm.group(1)
    dim = None
    dm = re.search(r"DIMENSION\s*:?\s*(\d+)", text)
    if dm:
        dim = int(dm.group(1))

    coords: list[tuple[float, float]] = []
    weights: list[float] = []
    section = None
    for ln in text.splitlines():
        s = ln.strip()
        if not s:
            continue
        head = s.split()[0].rstrip(":")
        if head in _SECTION_KEYWORDS:
            section = head
            if section == "EOF":
                break
            continue
        if section == "NODE_COORD_SECTION":
            parts = s.split()
            coords.append((float(parts[1]), float(parts[2])))
        elif section == "EDGE_WEIGHT_SECTION":
            weights.extend(float(v) for v in s.split())
        # DISPLAY_DATA_SECTION / other sections: skipped

    if ewt == "EXPLICIT":
        if fmt is None:
            raise ValueError(
                "EDGE_WEIGHT_TYPE EXPLICIT needs an EDGE_WEIGHT_FORMAT field")
        if fmt not in SUPPORTED_EDGE_WEIGHT_FORMATS:
            raise ValueError(
                f"unsupported EDGE_WEIGHT_FORMAT {fmt!r}; supported: "
                f"{', '.join(SUPPORTED_EDGE_WEIGHT_FORMATS)}")
        if not weights:
            raise ValueError("EXPLICIT instance has no EDGE_WEIGHT_SECTION")
        if dim is None:
            raise ValueError("EXPLICIT instance has no DIMENSION field")
        return TSPInstance(name=name,
                           dist_matrix=_explicit_matrix(weights, dim, fmt),
                           edge_weight_type="EXPLICIT")

    if not coords:
        raise ValueError("no NODE_COORD_SECTION found")
    if dim is not None and len(coords) != dim:
        raise ValueError(
            f"NODE_COORD_SECTION has {len(coords)} rows, DIMENSION says {dim}")
    return TSPInstance(name=name, coords=np.asarray(coords), edge_weight_type=ewt)


def load_tsplib(path) -> TSPInstance:
    """Parse a .tsp file from disk (fetch-free fixture path)."""
    import os
    with open(path) as f:
        return parse_tsplib(f.read(), name=os.path.splitext(
            os.path.basename(path))[0])


def find_tsplib(name: str, dirs=("examples", ".")) -> Optional[TSPInstance]:
    """Look for ``<name>.tsp`` under the given directories (repo root first).

    The fixture path for paper-scale instances: drop e.g. ``pr2392.tsp``
    into ``examples/`` and benchmarks pick it up — no network fetch, no
    data files shipped in the repo.  Returns None when absent so callers
    can fall back to synthetic instances of the same size.
    """
    import os
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    for d in dirs:
        for base in (d, os.path.join(root, d)):
            p = os.path.join(base, f"{name}.tsp")
            if os.path.exists(p):
                return load_tsplib(p)
    return None


def pad_instance(instance: TSPInstance, n_pad: int) -> TSPInstance:
    """Pad an instance to ``n_pad`` cities with masked phantom cities.

    Phantom cities (indices >= instance.n) sit at infinite distance from
    every real city and from each other (diagonal stays 0), so their
    heuristic eta = 1/d is exactly 0 and no masked code path can ever
    prefer them.  The solver engine (solver/batch.py) buckets instances by
    padded size so one vmapped program serves many heterogeneous instances;
    DESIGN.md §8 records the masking invariants.
    """
    n = instance.n
    if n_pad < n:
        raise ValueError(f"n_pad={n_pad} < instance size {n}")
    if n_pad == n:
        return instance
    d = np.full((n_pad, n_pad), np.inf, dtype=np.float32)
    d[:n, :n] = instance.distances()
    np.fill_diagonal(d, 0.0)
    return TSPInstance(name=instance.name, dist_matrix=d,
                       edge_weight_type=instance.edge_weight_type,
                       known_optimum=instance.known_optimum)


def nn_lists(dist: Array, k: int, n_actual: Optional[int] = None) -> Array:
    """(n, min(k, n-1)) int32 nearest-neighbour lists, self excluded.

    Paper §II (nn = 15..40), hardened for the solver/sparse subsystems:

    - ``k >= n-1`` clamps to n-1 (a city has at most n-1 neighbours) instead
      of erroring inside top_k;
    - ties on equal distances break **deterministically by city index**
      (stable argsort), so candidate lists are reproducible across runs and
      backends — grid instances have massive distance ties;
    - ``n_actual`` (padded instances, DESIGN.md §8): phantom cities
      (index >= n_actual) never appear in any list.  Surplus positions — a
      row needs k entries but only n_actual-1 real neighbours exist, or the
      row itself is phantom — are filled with the **row's own index**: the
      current city is always visited, so a self entry is masked to weight 0
      by every selection rule and is never selectable (the same sentinel the
      sparse overflow slots use).
    """
    n = dist.shape[0]
    k = max(1, min(k, n - 1))
    d = dist + jnp.eye(n, dtype=dist.dtype) * jnp.finfo(dist.dtype).max
    idx = jnp.argsort(d, axis=-1, stable=True)[:, :k].astype(jnp.int32)
    if n_actual is not None:
        self_idx = jnp.arange(n, dtype=jnp.int32)[:, None]
        na = jnp.asarray(n_actual, jnp.int32)
        idx = jnp.where((idx < na) & (self_idx < na), idx, self_idx)
    return idx


def edge_sum(d: Array) -> Array:
    """Associativity-fixed sum over the last axis (per-edge lengths -> tour
    length): explicit pairwise halving built from elementwise adds, which
    XLA cannot re-associate.  A plain ``.sum(-1)`` compiles to different
    reduction splits in different program contexts (observed: the dense
    construction program and the sparse one disagreed by 1 ulp), which
    would silently void every cross-route bitwise length contract — the
    dense/sparse k = n-1 equivalence, batched == solo, kernel == ref.
    Every tour-length reduction in the repo goes through this helper.
    """
    while d.shape[-1] > 1:
        if d.shape[-1] % 2:
            d = jnp.concatenate(
                [d, jnp.zeros(d.shape[:-1] + (1,), d.dtype)], axis=-1)
        d = d[..., 0::2] + d[..., 1::2]
    return d[..., 0]


def tour_length(dist: Array, tour: Array, n_actual: Optional[Array] = None) -> Array:
    """Closed-tour length; tour (..., n) int32 city permutation.

    With ``n_actual`` (a traced scalar, per-instance under vmap) the tour is
    treated as a padded tour whose real cities occupy positions
    ``0..n_actual-1``: the closing edge runs from position n_actual-1 back to
    position 0 and phantom-tail edges contribute 0 (masked with ``where``,
    never multiplied — phantom distances are inf).
    """
    nxt = jnp.roll(tour, -1, axis=-1)
    if n_actual is None:
        return edge_sum(jnp.take_along_axis(
            dist[tour], nxt[..., None], axis=-1)[..., 0])
    idx = jnp.arange(tour.shape[-1], dtype=jnp.int32)
    nxt = jnp.where(idx == n_actual - 1, tour[..., :1], nxt)
    d = jnp.take_along_axis(dist[tour], nxt[..., None], axis=-1)[..., 0]
    return edge_sum(jnp.where(idx < n_actual, d, 0.0))


def heuristic_matrix(dist: Array) -> Array:
    """eta = 1/d with safe diagonal (paper eq. 1)."""
    eps = jnp.asarray(1e-10, dist.dtype)
    return 1.0 / jnp.maximum(dist, eps)


def is_valid_tour(tour: np.ndarray) -> bool:
    tour = np.asarray(tour)
    n = tour.shape[-1]
    return bool((np.sort(tour, axis=-1) == np.arange(n)).all())


def nearest_neighbour_tour(dist: np.ndarray, start: int = 0) -> tuple[np.ndarray, float]:
    """Greedy NN heuristic tour — used for tau0 initialisation (Dorigo &
    Stützle: tau0 = m / C_nn) and as a quality yardstick."""
    dist = np.asarray(dist)
    n = dist.shape[0]
    visited = np.zeros(n, dtype=bool)
    tour = np.empty(n, dtype=np.int32)
    cur = start
    tour[0] = cur
    visited[cur] = True
    for i in range(1, n):
        d = np.where(visited, np.inf, dist[cur])
        cur = int(np.argmin(d))
        tour[i] = cur
        visited[cur] = True
    length = float(dist[tour, np.roll(tour, -1)].sum())
    return tour, length
