"""Beyond-paper integration: the ACO engine applied to the framework's own
scheduling problem — layer-to-pipeline-stage placement (DESIGN.md §5).

Problem: assign L heterogeneous layers (per-layer FLOP cost c_i, inter-layer
activation traffic t_i) to S stages. Cost = max stage load (pipeline
bottleneck) + lambda * sum of cut traffic (activations crossing stages).
Contiguity is NOT assumed (mixture placements are valid for interleaved
pipelines), so the search space is S^L — a combinatorial problem the AS
engine handles the same way it handles the TSP: pheromone matrix (L, S),
per-step I-Roulette over stages, evaporation + quality-weighted deposit.

This reuses the paper's data-parallel construction pattern: all m ants pick
stage assignments for layer i simultaneously (an (m, S) tensor op per step).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import sampling

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PlacementProblem:
    """Hashable (jit-static) problem description; costs stored as tuples."""
    layer_costs: tuple             # (L,) per-layer compute cost
    edge_traffic: tuple            # (L,) activation bytes out of layer i
    n_stages: int
    comm_lambda: float = 0.25      # traffic weight vs load balance

    def __post_init__(self):
        object.__setattr__(self, "layer_costs",
                           tuple(float(x) for x in self.layer_costs))
        object.__setattr__(self, "edge_traffic",
                           tuple(float(x) for x in self.edge_traffic))

    @property
    def n_layers(self) -> int:
        return len(self.layer_costs)


@dataclasses.dataclass(frozen=True)
class PlacementConfig:
    ants: int = 64
    iterations: int = 60
    alpha: float = 1.0
    beta: float = 2.0
    rho: float = 0.3
    q: float = 1.0
    seed: int = 0


def assignment_cost(prob: PlacementProblem, assign: Array) -> Array:
    """assign (..., L) int32 -> scalar cost per assignment."""
    c = jnp.asarray(prob.layer_costs, jnp.float32)
    t = jnp.asarray(prob.edge_traffic, jnp.float32)
    s = prob.n_stages
    onehot = jax.nn.one_hot(assign, s, dtype=jnp.float32)  # (..., L, S)
    loads = jnp.einsum("...ls,l->...s", onehot, c)
    bottleneck = loads.max(-1)
    cuts = (assign[..., 1:] != assign[..., :-1]).astype(jnp.float32)
    comm = (cuts * t[:-1]).sum(-1)
    return bottleneck + prob.comm_lambda * comm


@partial(jax.jit, static_argnames=("prob", "cfg"))
def _step(tau: Array, key: Array, prob: PlacementProblem,
          cfg: PlacementConfig) -> tuple[Array, Array, Array]:
    L = prob.n_layers
    s = prob.n_stages
    m = cfg.ants
    c = jnp.asarray(prob.layer_costs, jnp.float32)
    mean_load = c.sum() / s

    def body(carry, i):
        loads, prev = carry                     # (m, S), (m,)
        k = jax.random.fold_in(key, i)
        # heuristic: prefer under-loaded stages and staying on prev stage
        head = 1.0 / (1.0 + loads / mean_load)              # (m, S)
        stay = 1.0 + 0.5 * jax.nn.one_hot(prev, s)
        w = (tau[i][None, :] ** cfg.alpha) * ((head * stay) ** cfg.beta)
        pick = sampling.iroulette(k, w)                      # (m,)
        loads = loads + jax.nn.one_hot(pick, s) * c[i]
        return (loads, pick), pick

    loads0 = jnp.zeros((m, s), jnp.float32)
    prev0 = jnp.zeros((m,), jnp.int32)
    (_, _), picks = jax.lax.scan(body, (loads0, prev0), jnp.arange(L))
    assign = picks.T.astype(jnp.int32)                       # (m, L)
    costs = assignment_cost(prob, assign)

    # Elitist AS update: only the best quartile of ants deposits, weighted
    # by solution quality (flat all-ants deposit washes out on this problem
    # because costs cluster tightly around the balanced optimum).
    thresh = jnp.quantile(costs, 0.25)
    w = jnp.where(costs <= thresh,
                  cfg.q * costs.min() / jnp.maximum(costs, 1e-9), 0.0)
    dep = jnp.einsum("m,mls->ls", w,
                     jax.nn.one_hot(assign, s, dtype=jnp.float32))
    tau = (1 - cfg.rho) * tau + dep
    best = jnp.argmin(costs)
    return tau, assign[best], costs[best]


def solve(prob: PlacementProblem, cfg: PlacementConfig = PlacementConfig()
          ) -> tuple[np.ndarray, float]:
    L = prob.n_layers
    tau = jnp.full((L, prob.n_stages), 1.0, jnp.float32)
    key = jax.random.PRNGKey(cfg.seed)
    best_a, best_c = None, np.inf
    for it in range(cfg.iterations):
        tau, a, cst = _step(tau, jax.random.fold_in(key, it), prob, cfg)
        if float(cst) < best_c:
            best_c = float(cst)
            best_a = np.asarray(a)
    return best_a, best_c


def uniform_baseline(prob: PlacementProblem) -> tuple[np.ndarray, float]:
    """Contiguous equal-layer-count split (the standard default)."""
    L = prob.n_layers
    s = prob.n_stages
    assign = np.minimum((np.arange(L) * s) // L, s - 1).astype(np.int32)
    return assign, float(assignment_cost(prob, jnp.asarray(assign)))
