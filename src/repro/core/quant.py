"""Quantised resident pheromone store (DESIGN.md §15).

The pheromone matrix is the one large *resident* tensor the solver fabric
carries per colony — smooth, bounded (MMAS clamps it explicitly), and
noise-tolerant, exactly the profile that tolerates reduced precision.
This module packages tau as a ``QuantTau`` pytree so every layer that
*holds* tau (engine slot stacks, streaming pools, sharded placement,
checkpoints, sparse pages) keeps the low-precision payload resident,
while every layer that *computes* on tau (evaporate/deposit/clamp/ACS)
dequantises to a transient fp32 tensor, updates, and requantises on
store.

Representation per ``ACOConfig.tau_dtype``:

- ``fp32``  — no wrapper at all: ColonyState.tau stays the raw float32
  array, the pytree structure is unchanged, and every fp32 route is
  bitwise-identical to the unquantised tree (the load-bearing exactness
  contracts of PRs 2-6 are untouched).
- ``bf16``  — payload ``q`` is tau cast to bfloat16 (same exponent range
  as fp32, so no scale is needed; ``scale``/``err`` are zero-width
  leaves and cost 0 resident bytes).  Dequant is exactly ``astype(f32)``.
- ``int8``  — payload ``q`` is int8 with a per-row fp32 ``scale``
  (``max(|row|)/127``, optim.compression.quantize_int8(axis=-1)).
  Per-row granularity matters: MMAS rows saturate at very different
  levels and a per-tensor scale would crush cold rows to zero.

Rounding (``ACOConfig.tau_round``): ``stochastic`` (default) rounds with
``floor(y + uniform)`` — unbiased, so trail values below half a
quantisation step (int8 cannot represent the full MMAS tau_max/tau_min =
2n ratio for n >= 64) survive in expectation instead of deterministically
collapsing to the floor; ``nearest`` is deterministic round-to-nearest.

Compensation (``ACOConfig.tau_compensation``): carry the fp32
quantisation residual in ``err`` and add it back before the next
requantise — the error-feedback invariant of optim/compression.py
(``q*scale + err == the exact accumulated fp32 value``), which makes
repeated deposits exact in the limit.  Off by default: the residual is a
full-size fp32 leaf, which forfeits the resident-bytes win (int8+err is
5 bytes/entry); stochastic rounding gives the unbiasedness cheaply.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.optim.compression import quantize_int8

Array = jax.Array

TAU_DTYPES = ("fp32", "bf16", "int8")
TAU_ROUNDS = ("stochastic", "nearest")


class QuantTau(NamedTuple):
    """Quantised pheromone leaf bundle; rides anywhere a tau Array did.

    All three leaves always exist so the pytree structure is static per
    config: unused leaves (bf16 scale, compensation-off err) are
    zero-width ``(rows, 0)`` arrays — 0 resident bytes, and every generic
    pytree operation in the fabric (stack / .at[ix].set / where-merge /
    pad / shard / checkpoint) handles them untouched.
    """
    q: Array        # payload: int8 or bfloat16, same shape as the fp32 tau
    scale: Array    # (rows, 1) f32 per-row scale (int8), or (rows, 0)
    err: Array      # f32 error-feedback residual (compensation), or (rows, 0)


TauLike = Union[Array, QuantTau]


def validate_tau_dtype(tau_dtype: str, tau_round: str = "stochastic") -> None:
    if tau_dtype not in TAU_DTYPES:
        raise ValueError(
            f"unknown tau_dtype {tau_dtype!r}; supported: "
            + " | ".join(TAU_DTYPES))
    if tau_round not in TAU_ROUNDS:
        raise ValueError(
            f"unknown tau_round {tau_round!r}; supported: "
            + " | ".join(TAU_ROUNDS))


def is_quantised(tau_dtype: str) -> bool:
    validate_tau_dtype(tau_dtype)
    return tau_dtype != "fp32"


def _zero_width(x: Array) -> Array:
    return jnp.zeros(x.shape[:-1] + (0,), jnp.float32)


def _round_bf16(x: Array, key: Optional[Array]) -> Array:
    """fp32 -> bf16 cast; stochastic when a key is given.

    Stochastic bf16 rounding adds uniform bits below the truncation point
    of the fp32 significand and truncates: P(round up) equals the
    fractional distance to the next representable bf16, i.e. unbiased.
    A mantissa carry that overflows into the exponent *is* the correct
    round-up to the next binade.
    """
    if key is None:
        return x.astype(jnp.bfloat16)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    r = jax.random.bits(key, x.shape, jnp.uint32) & jnp.uint32(0xFFFF)
    bits = (bits + r) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(bits, jnp.float32).astype(jnp.bfloat16)


def quantise(x: Array, tau_dtype: str, *, compensation: bool = False,
             key: Optional[Array] = None,
             err: Optional[Array] = None) -> QuantTau:
    """fp32 tau -> QuantTau.  ``err`` carries the previous residual
    (error feedback); ``key`` switches to stochastic rounding."""
    validate_tau_dtype(tau_dtype)
    assert tau_dtype != "fp32", "fp32 tau is stored raw, not wrapped"
    if x.shape[-1] == 0:
        # zero-width store (e.g. sparse_overflow=0 pages): no values to
        # round, but keep the same leaf structure/dtypes as the non-empty
        # case so the pytree stays static per config.
        q = x.astype(jnp.bfloat16 if tau_dtype == "bf16" else jnp.int8)
        scale = (jnp.ones(x.shape[:-1] + (1,), jnp.float32)
                 if tau_dtype == "int8" else _zero_width(x))
        return QuantTau(q=q, scale=scale, err=_zero_width(x))
    work = x if err is None or err.shape[-1] == 0 else x + err
    if tau_dtype == "bf16":
        q = _round_bf16(work, key)
        scale = _zero_width(x)
        deq = q.astype(jnp.float32)
    else:
        q, scale = quantize_int8(work, key=key, axis=-1)
        deq = q.astype(jnp.float32) * scale
    new_err = (work - deq) if compensation else _zero_width(x)
    return QuantTau(q=q, scale=scale, err=new_err)


def requantise(x: Array, prev: QuantTau, tau_dtype: str,
               key: Optional[Array] = None) -> QuantTau:
    """Quantise-on-store after an fp32 update step, carrying the previous
    compensation residual (its width — 0 or full — is the static flag)."""
    comp = prev.err.shape[-1] > 0
    return quantise(x, tau_dtype, compensation=comp, key=key, err=prev.err)


def dequantise(tau: TauLike) -> Array:
    """Any tau representation -> transient fp32 (identity for raw fp32)."""
    if not isinstance(tau, QuantTau):
        return tau
    if tau.q.dtype == jnp.int8:
        return tau.q.astype(jnp.float32) * tau.scale
    return tau.q.astype(jnp.float32)


def dequantise_rows(rows: Array, scale_rows: Optional[Array]) -> Array:
    """Dequantise already-gathered payload rows: the sparse pure route
    gathers (m, K) pages first and dequantises the transient — the
    resident (n, k) store never materialises in fp32."""
    if rows.dtype == jnp.int8:
        return rows.astype(jnp.float32) * scale_rows
    if rows.dtype == jnp.bfloat16:
        return rows.astype(jnp.float32)
    return rows


def tau_nbytes(tau: TauLike) -> int:
    """Resident bytes of one tau representation (payload + scales + err)."""
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(tau))


def round_key(tau_round: str, key: Array) -> Optional[Array]:
    """The PRNG key the quantise-on-store step consumes, or None for
    deterministic nearest rounding (the key is still split off by the
    caller either way, so switching rounding modes never shifts the
    construction key trajectory)."""
    return key if tau_round == "stochastic" else None
