"""Tour-construction strategies (paper §IV.A).

The strategy ladder mirrors Table II of the paper:

1. ``task_baseline``  task parallelism, one logical thread per ant,
                      heuristic values recomputed at every construction step
                      (the paper's version 1 — "redundantly calculates
                      heuristic information").
2. ``task_choice``    task parallelism + precomputed choice_info
                      (the paper's version 2, "Choice kernel").
3. ``nn_list``        nearest-neighbour candidate lists with best-unvisited
                      fallback (the paper's version 4; versions 5/6 are
                      GPU-memory-placement variants with no TPU analogue —
                      see DESIGN.md §2).
4. ``data_parallel``  the paper's contribution (version 7/8): the whole
                      colony's step is one (m, n) tensor op — gather choice
                      rows, mask tabu, multiply by per-city randoms, reduce.
                      On TPU the city axis lives in VPU lanes; the Pallas
                      ``tour_select`` kernel (kernels/tour_select.py) is the
                      tiled in-VMEM version and can be injected via
                      ``step_impl``.

All variants share one lax.scan skeleton so that solution-quality parity
(claim C6) is attributable to the selection semantics only.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import sampling, tsp

Array = jax.Array


class TourState(NamedTuple):
    cur: Array      # (m,) int32 current city
    visited: Array  # (m, n) bool tabu list


class TourResult(NamedTuple):
    tours: Array    # (m, n) int32 city permutations
    lengths: Array  # (m,) float32 closed-tour lengths


def place_ants(key: Array, m: int, n: int,
               n_actual: Optional[Array] = None) -> Array:
    """Random initial city per ant (paper: 'ants are randomly placed').

    ``n_actual`` (traced scalar) bounds placement to the real cities of a
    padded instance; the draw is bitwise identical to the unpadded draw for
    the same key (threefry bits are counter-mode in the ant index).
    """
    hi = n if n_actual is None else n_actual
    return jax.random.randint(key, (m,), 0, hi, dtype=jnp.int32)


def _init_state(start: Array, n: int) -> TourState:
    m = start.shape[0]
    visited = jnp.zeros((m, n), jnp.bool_).at[jnp.arange(m), start].set(True)
    return TourState(start, visited)


def _finish(start: Array, steps: Array, dist: Array,
            n_actual: Optional[Array] = None) -> TourResult:
    """steps (n-1, m) emitted cities -> tours (m, n) + lengths."""
    tours = jnp.concatenate([start[None, :], steps], axis=0).T  # (m, n)
    tours = tours.astype(jnp.int32)
    if n_actual is not None:
        return TourResult(tours, tsp.tour_length(dist, tours, n_actual))
    nxt = jnp.roll(tours, -1, axis=-1)
    lengths = tsp.edge_sum(dist[tours, nxt])
    return TourResult(tours, lengths)


StepImpl = Callable[[Array, Array, TourState, int, dict], Array]
# (key, choice_info, state, t, extras) -> next city (m,)
# Steps are MODULE-LEVEL functions keyed by (method, selection) so that
# repeated construct_tours calls hit the jit cache (a fresh closure per call
# would retrace every time — observed as ~1.4 s/call of pure compile).


def _make_dense_step(selector: str, draw_mode: str = "packed") -> StepImpl:
    sel = sampling.get_selector(selector, draw_mode)

    def step(key, choice_info, st, t, extras):
        del t, extras
        w = choice_info[st.cur] * (~st.visited)          # (m, n)
        return sel(key, w)

    return step


def _make_recompute_step(selector: str, draw_mode: str = "packed"
                         ) -> StepImpl:
    """Paper's baseline: recompute tau^a * eta^b for the current row each
    step (tau/eta/alpha/beta arrive as operands via ``extras``)."""
    sel = sampling.get_selector(selector, draw_mode)

    def step(key, choice_info, st, t, extras):
        del choice_info, t
        w = (extras["tau"][st.cur] ** extras["alpha"]
             * extras["eta"][st.cur] ** extras["beta"]) * (~st.visited)
        return sel(key, w)

    return step


def _make_nn_step(selector: str, lazy: bool = True,
                  draw_mode: str = "packed") -> StepImpl:
    """NN-list construction: sample among unvisited candidates; if the whole
    candidate set is visited, fall back to the best unvisited city by choice
    value (paper §II: 'selects the best neighbour according to eq. 1').

    ``lazy`` (the default) gates the dense O(m*n) fallback behind a
    count-gated ``lax.cond``: the (m, n) row gather + argmax only runs on
    steps where at least one ant has exhausted its candidate set, so an
    iteration costs O(m*n*k) + (fallback steps) * O(m*n) instead of an
    unconditional O(m*n^2) — the asymptotic win candidate lists exist for.
    Under vmap (solver/engine.run_batch batches colony_step) cond lowers to
    select and both branches run every step; the lazy win applies to solo /
    island colonies, which is where the paper's Table II measurement lives.
    ``lazy=False`` keeps the pre-overhaul unconditional fallback, registered
    as ``nn_list_eager`` purely as the regression baseline for
    benchmarks/construction_profile.py.  Both variants are bitwise
    identical in output — the fallback value is only consumed where
    ``have`` is False.
    """
    sel = sampling.get_selector(selector, draw_mode)

    def step(key, choice_info, st, t, extras):
        del t
        nn = extras["nn"]
        m = st.cur.shape[0]
        ants = jnp.arange(m)
        cand = nn[st.cur]                                   # (m, k)
        cw = choice_info[st.cur[:, None], cand]             # (m, k)
        cmask = ~st.visited[ants[:, None], cand]
        wc = cw * cmask
        have = wc.sum(-1) > 0
        local = sel(key, wc)                                # (m,) in [0, k)
        nxt_nn = cand[ants, local]

        def dense_fallback(_):
            w_full = choice_info[st.cur] * (~st.visited)    # (m, n)
            return jnp.argmax(w_full, axis=-1).astype(jnp.int32)

        if lazy:
            nxt_fb = jax.lax.cond(jnp.all(have), lambda _: nxt_nn,
                                  dense_fallback, None)
        else:
            nxt_fb = dense_fallback(None)
        return jnp.where(have, nxt_nn, nxt_fb)

    return step


def _draw_step_uniform(key: Array, shape: tuple, dtype,
                       draw_mode: str) -> Array:
    """The per-(ant, city) uniform tensor the kernel steps consume: packed
    (flat threefry counters, the historical bitwise behaviour) or counter
    mode (width-invariant bits, solver/programs.py neighbour routing)."""
    if draw_mode == "counter":
        return sampling.counter_uniform(key, shape, minval=1e-6,
                                        maxval=1.0).astype(dtype)
    return jax.random.uniform(key, shape, dtype, minval=1e-6, maxval=1.0)


def _make_pallas_step(selector: str, draw_mode: str = "packed") -> StepImpl:
    def step(key, choice_info, st, t, extras):
        del t
        from repro.kernels import ops as kops
        rows = choice_info[st.cur]
        u = _draw_step_uniform(key, rows.shape, rows.dtype, draw_mode)
        return kops.tour_select(rows, st.visited, u, selector,
                                extras["n_actual"])

    return step


def _make_fused_step(selector: str, alpha: float, beta: float,
                     draw_mode: str = "packed") -> StepImpl:
    """Fused choice->select kernel step (kernels/fused_select.py): the row
    gather, tau^alpha*eta^beta weighting, tabu/phantom masking and selection
    run in one pass over tiles — no (m, n) weight matrix, and no (n, n)
    choice-matrix precompute on this route (aco.colony_step skips it).

    alpha/beta are static kernel parameters, so this step is built inside
    ``_construct``'s trace (cached per static (alpha, beta) jit key) rather
    than registered in ``_STEPS``; per-instance traced exponents are
    rejected upstream (kernels.ops.check_kernel_route).
    """
    def step(key, choice_info, st, t, extras):
        del choice_info, t
        from repro.kernels import ops as kops
        u = _draw_step_uniform(key, st.visited.shape, jnp.float32,
                               draw_mode)
        # Quantised tau (core/quant.py): extras["tau"] carries the resident
        # int8/bf16 payload and the kernel dequantises per tile.  The
        # payload dtype is static at trace time, so passing the per-row
        # scale only for int8 adds no new jit keys.
        scale = (extras["tau_scale"]
                 if extras["tau"].dtype == jnp.int8 else None)
        return kops.fused_select(extras["tau"], extras["eta"], st.cur,
                                 st.visited, u, alpha, beta,
                                 extras["n_actual"], selector,
                                 tau_scale=scale)

    return step


_STEPS: dict[tuple[str, str, str], StepImpl] = {}
for _dm in sampling.DRAW_MODES:
    for _sel in sampling.SELECTORS:
        _STEPS[("data_parallel", _sel, _dm)] = _make_dense_step(_sel, _dm)
        _STEPS[("task_choice", _sel, _dm)] = _make_dense_step(
            "roulette" if _sel == "iroulette" else _sel, _dm)
        _STEPS[("task_baseline", _sel, _dm)] = \
            _make_recompute_step("roulette", _dm)
        _STEPS[("nn_list", _sel, _dm)] = _make_nn_step(_sel, draw_mode=_dm)
        _STEPS[("nn_list_eager", _sel, _dm)] = \
            _make_nn_step(_sel, lazy=False, draw_mode=_dm)
        _STEPS[("pallas", _sel, _dm)] = _make_pallas_step(_sel, _dm)


@partial(jax.jit, static_argnames=("n", "method", "selection", "masked",
                                   "alpha_s", "beta_s", "draw_mode"))
def _construct(key: Array, choice_info: Array, dist: Array, start: Array,
               extras: dict, n: int, method: str,
               selection: str, masked: bool = False,
               alpha_s: Optional[float] = None,
               beta_s: Optional[float] = None,
               draw_mode: str = "packed") -> TourResult:
    # alpha_s/beta_s: static exponents for the fused kernel step only (its
    # closure is built per trace; the jit cache is keyed on their values).
    if method == "fused":
        step_impl = _make_fused_step(selection, alpha_s, beta_s, draw_mode)
    else:
        step_impl = _STEPS[(method, selection, draw_mode)]
    st0 = _init_state(start, n)
    m = start.shape[0]
    ants = jnp.arange(m)

    def body(st: TourState, t: Array):
        k = jax.random.fold_in(key, t)
        nxt = step_impl(k, choice_info, st, t, extras)
        if masked:
            # Padded instance: once the real cities are exhausted (phantom
            # weights are all 0 — eta is 0 there), emit the phantom tail in
            # fixed index order, so every padded tour is the real-city
            # permutation at positions [0, n_actual) followed by cities
            # n_actual..n-1.  This invariant is what makes masked
            # tour-length, deposit and local search exact (DESIGN.md §8).
            nxt = jnp.where(t < extras["n_actual"], nxt, t).astype(jnp.int32)
        visited = st.visited.at[ants, nxt].set(True)
        return TourState(nxt, visited), nxt

    _, steps = jax.lax.scan(body, st0, jnp.arange(1, n))
    return _finish(start, steps, dist, extras["n_actual"] if masked else None)


def construct_tours(
    key: Array,
    dist: Array,
    choice_info: Array,
    m: int,
    method: str = "data_parallel",
    selection: str = "iroulette",
    nn: Optional[Array] = None,
    tau: Optional[Array] = None,
    eta: Optional[Array] = None,
    alpha: float = 1.0,
    beta: float = 2.0,
    step_impl: Optional[StepImpl] = None,
    n_actual: Optional[Array] = None,
    tau_scale: Optional[Array] = None,
    draw_mode: str = "packed",
) -> TourResult:
    """Build m complete tours under the given strategy.

    choice_info: (n, n) precomputed tau^alpha * eta^beta (ignored by
    ``task_baseline``, which recomputes it row-wise each step).
    Beyond the paper ladder, two more methods: ``fused`` (the fused
    choice->select Pallas kernel, kernels/fused_select.py — requires
    tau/eta and *static* alpha/beta; choice_info is ignored) and
    ``nn_list_eager`` (the pre-overhaul unconditional dense fallback, kept
    as the regression baseline for benchmarks/construction_profile.py).
    ``step_impl``: pass the string "pallas" via method, or a custom StepImpl
    (custom callables bypass the jit cache — fine inside an outer jit like
    aco.colony_step, slow if called repeatedly in eager mode).
    ``n_actual``: traced scalar count of real cities for padded instances
    (solver/); ant placement and selection are restricted to real cities and
    the phantom tail is emitted in fixed order. Returned lengths are masked
    real-tour lengths. Not supported for step_impl injection.
    ``draw_mode``: "packed" (default, historical bitwise behaviour) or
    "counter" — width-invariant per-(ant, city) randomness (sampling.py),
    the exactness basis of neighbour-bucket routing (DESIGN.md §16).
    """
    n = dist.shape[0]
    masked = n_actual is not None
    kp, kc = jax.random.split(key)
    start = place_ants(kp, m, n, n_actual)
    zero = jnp.zeros((1, 1), jnp.float32)
    extras = {
        "tau": tau if tau is not None else zero,
        "tau_scale": tau_scale if tau_scale is not None else zero,
        "eta": eta if eta is not None else zero,
        "alpha": jnp.float32(alpha),
        "beta": jnp.float32(beta),
        "nn": nn if nn is not None else jnp.zeros((1, 1), jnp.int32),
        "n_actual": (jnp.asarray(n_actual, jnp.int32) if masked
                     else jnp.asarray(n, jnp.int32)),
    }
    if step_impl is not None:
        assert not masked, "n_actual is not supported with step_impl injection"
        # custom injection path (un-cached trace)
        def _custom(key_, ci_, dist_, start_, extras_):
            st0 = _init_state(start_, n)
            ants = jnp.arange(start_.shape[0])

            def body(st, t):
                k = jax.random.fold_in(key_, t)
                nxt = step_impl(k, ci_, st, t)
                return TourState(nxt, st.visited.at[ants, nxt].set(True)), nxt

            _, steps = jax.lax.scan(body, st0, jnp.arange(1, n))
            return _finish(start_, steps, dist_)

        return _custom(kc, choice_info, dist, start, extras)
    if method not in ("data_parallel", "task_choice", "task_baseline",
                      "nn_list", "nn_list_eager", "pallas", "fused"):
        raise ValueError(f"unknown construction method {method}")
    if method == "task_baseline":
        assert tau is not None and eta is not None
    if method in ("nn_list", "nn_list_eager"):
        assert nn is not None
    alpha_s = beta_s = None
    if method == "fused":
        assert tau is not None and eta is not None
        if isinstance(alpha, jax.core.Tracer) or \
                isinstance(beta, jax.core.Tracer):
            from repro.kernels import ops as kops
            raise kops.UnsupportedKernelRoute(
                "fused construction kernel needs static alpha/beta; traced "
                "per-instance exponents run the pure-JAX route")
        alpha_s, beta_s = float(alpha), float(beta)
    if draw_mode not in sampling.DRAW_MODES:
        raise ValueError(f"unknown draw_mode {draw_mode!r}; "
                         f"supported: {', '.join(sampling.DRAW_MODES)}")
    return _construct(kc, choice_info, dist, start, extras, n, method,
                      selection, masked, alpha_s, beta_s, draw_mode)


def choice_matrix(tau: Array, eta: Array, alpha, beta) -> Array:
    """The paper's Choice kernel: precompute tau^a * eta^b once per iteration.

    Static integer exponents take the cheap path (XLA folds x**1, x**2 to
    mults); traced exponents (per-instance Hyper operands, DESIGN.md §9)
    take the generic pow.  The Pallas version lives in kernels/choice_info.py.
    """
    def ipow(x: Array, p) -> Array:
        if not isinstance(p, (int, float)):
            return x ** p               # traced per-instance exponent
        if p == 1.0:
            return x
        if p == 2.0:
            return x * x
        if p == int(p) and 0 < int(p) <= 4:
            y = x
            for _ in range(int(p) - 1):
                y = y * x
            return y
        return x ** p

    return ipow(tau, alpha) * ipow(eta, beta)
