"""GPU-batched local search: NN-restricted 2-opt and Or-opt (DESIGN.md §7).

The paper parallelises tour construction and pheromone update; the strong
follow-ups (Chitty's candidate-list 2-opt, Skinderowicz's iteration-best
local search) couple ACO with on-device local search. This module improves
all ``m`` ant tours per iteration entirely on-device, in a form that jits,
scans across ACO iterations and shards across the island mesh:

- **2-opt**, restricted to the instance's nearest-neighbour lists: for every
  tour position ``i`` (city ``a``, successor ``a'``) and every candidate
  ``c`` in ``nn[a]`` (position ``j``, successor ``c'``), the move replaces
  edges (a, a') and (c, c') with (a, c) and (a', c') by reversing the
  segment between them.  All ``n*k`` move deltas per ant form one
  ``(m, n*k)`` tensor; a masked argmin (best-improvement) or first-True
  argmax (first-improvement) picks one move per ant per round, applied as a
  vectorised segment-reversal gather.  Rounds run inside a bounded
  ``lax.scan`` so the whole search is one compiled program.
- **Or-opt** (segment relocation): segments of length L = 1..seg_max are
  removed and re-inserted after a candidate city from ``nn[s0]``.  The move
  is applied with a fractional-sort-key argsort, which keeps the update a
  fixed-shape tensor op.

Both passes are strictly non-worsening: a move is only applied when its
delta clears ``-min_delta`` (degenerate moves — candidate equal to the
current successor/predecessor — are masked explicitly, so float cancellation
can never fabricate an improvement).

``STRATEGIES`` mirrors ``pheromone.STRATEGIES``: a name -> round-function
registry that ``ACOConfig.local_search`` selects from.  The 2-opt delta
scan optionally routes through the Pallas kernel (kernels/two_opt.py) via
``use_pallas``, identical in output to the pure-JAX path.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref

from . import tsp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LocalSearchConfig:
    kind: str = "2opt"           # none | 2opt | oropt | 2opt_oropt
    rounds: int = 24             # bounded improvement rounds (lax.scan length)
    improvement: str = "best"    # best | first (move choice per round)
    seg_max: int = 3             # Or-opt max relocated-segment length
    # Strict-improvement threshold in ABSOLUTE tour-length units: a move is
    # applied only when delta < -min_delta, which stops f32 cancellation
    # noise from ping-ponging zero-gain moves until rounds are exhausted.
    # The default suits coordinate scales O(1e3) (all in-repo generators);
    # scale it down for unit-scale instances or improvements below it are
    # silently ignored.
    min_delta: float = 1e-3
    use_pallas: bool = False     # 2-opt delta scan via kernels/two_opt.py


class Move(NamedTuple):
    delta: Array   # (m,) best/first move delta (+inf when none)
    i: Array       # (m,) tour position of the move anchor
    j: Array       # (m,) tour position of the candidate endpoint


def tour_positions(tours: Array) -> Array:
    """pos[ant, city] = position of city in that ant's tour."""
    m, n = tours.shape
    ants = jnp.arange(m)[:, None]
    steps = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (m, n))
    return jnp.zeros((m, n), jnp.int32).at[ants, tours].set(steps)


def _successors(tours: Array, n_actual: Optional[Array]) -> Array:
    """succ[ant, p] = city after position p.

    Unmasked this is roll(-1); with ``n_actual`` (padded tours, real prefix
    at positions [0, n_actual)) the real tour closes at position n_actual-1
    back to the city at position 0 — phantom-tail successors are garbage and
    must be masked out by the caller's ``valid`` tensor.
    """
    succ = jnp.roll(tours, -1, axis=-1)
    if n_actual is not None:
        idx = jnp.arange(tours.shape[-1], dtype=jnp.int32)
        succ = jnp.where(idx == n_actual - 1, tours[..., :1], succ)
    return succ


# --------------------------------------------------------------------------
# 2-opt
# --------------------------------------------------------------------------

def _two_opt_operands(dist: Array, nn: Array, tours: Array,
                      n_actual: Optional[Array] = None):
    """Gathered distance tensors for all (position, candidate) 2-opt moves.

    Returns (add1, add2, rem1, rem2, valid, j) each (m, n, k): the move at
    (ant, i, c) removes edges (a, a') and (c, c') and adds (a, c), (a', c').
    """
    m, n = tours.shape
    pos = tour_positions(tours)
    a = tours                                        # (m, n)
    succ = _successors(tours, n_actual)
    a_nxt = succ
    c = nn[a]                                        # (m, n, k)
    k = c.shape[-1]
    j = jnp.take_along_axis(pos, c.reshape(m, -1), axis=1).reshape(m, n, k)
    c_nxt = jnp.take_along_axis(
        succ, j.reshape(m, -1), axis=1).reshape(m, n, k)
    add1 = dist[a[..., None], c]                     # d(a, c)
    add2 = dist[a_nxt[..., None], c_nxt]             # d(a', c')
    rem1 = jnp.broadcast_to(dist[a, a_nxt][..., None], add1.shape)
    rem2 = dist[c, c_nxt]
    # degenerate moves share an edge with the tour: their true delta is 0,
    # but float cancellation could make it spuriously negative — mask them.
    valid = (c != a_nxt[..., None]) & (c_nxt != a[..., None])
    if n_actual is not None:
        # padded instance: anchors must sit in the real prefix and
        # candidates must be real cities — any phantom-touching move has
        # inf/NaN operands and is discarded here, before selection.
        i_pos = jnp.arange(n, dtype=jnp.int32)[None, :, None]
        valid = valid & (i_pos < n_actual) & (c < n_actual)
    return add1, add2, rem1, rem2, valid, j


def _reduce_moves(add1, add2, rem1, rem2, valid, cfg: LocalSearchConfig):
    """(m, n, k) move operands -> per-ant (delta, flat move index)."""
    m = add1.shape[0]
    flat = lambda x: x.reshape(m, -1)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        return kops.two_opt_best(
            flat(add1), flat(add2), flat(rem1), flat(rem2), flat(valid),
            thr=cfg.min_delta, mode=cfg.improvement)
    return kref.two_opt_best(flat(add1), flat(add2), flat(rem1), flat(rem2),
                             flat(valid), thr=cfg.min_delta,
                             mode=cfg.improvement)


def best_two_opt_move(dist: Array, nn: Array, tours: Array,
                      cfg: LocalSearchConfig,
                      n_actual: Optional[Array] = None) -> Move:
    add1, add2, rem1, rem2, valid, j = _two_opt_operands(
        dist, nn, tours, n_actual)
    m, n, k = j.shape
    val, idx = _reduce_moves(add1, add2, rem1, rem2, valid, cfg)
    safe = jnp.clip(idx, 0, n * k - 1)
    i_sel = (safe // k).astype(jnp.int32)
    j_sel = jnp.take_along_axis(j.reshape(m, -1), safe[:, None], axis=1)[:, 0]
    return Move(val, i_sel, j_sel)


def apply_two_opt(tours: Array, i: Array, j: Array, do: Array) -> Array:
    """Reverse positions (min(i,j), max(i,j)] per ant where ``do`` holds."""
    n = tours.shape[1]
    lo = jnp.minimum(i, j)[:, None]
    hi = jnp.maximum(i, j)[:, None]
    idx = jnp.arange(n, dtype=jnp.int32)[None, :]
    within = (idx > lo) & (idx <= hi)
    src = jnp.where(within, lo + 1 + hi - idx, idx)
    src = jnp.where(do[:, None], src, idx)
    return jnp.take_along_axis(tours, src, axis=1)


def two_opt_round(dist: Array, nn: Array, tours: Array,
                  cfg: LocalSearchConfig,
                  n_actual: Optional[Array] = None) -> Array:
    mv = best_two_opt_move(dist, nn, tours, cfg, n_actual)
    # masked moves have i, j < n_actual, so the reversal below never
    # touches the phantom tail of a padded tour.
    return apply_two_opt(tours, mv.i, mv.j, mv.delta < -cfg.min_delta)


# --------------------------------------------------------------------------
# Or-opt (segment relocation)
# --------------------------------------------------------------------------

def best_or_opt_move(dist: Array, nn: Array, tours: Array, seg_len: int,
                     cfg: LocalSearchConfig,
                     n_actual: Optional[Array] = None) -> Move:
    """Best relocation of a ``seg_len`` segment, candidates from nn[s0].

    Move (ant, p, c): remove the segment s0..s_end at positions
    [p, p+seg_len-1] (non-wrapping) and insert it between c and c's
    successor.  delta = d(prev,next) + d(c,s0) + d(s_end,c') -
    d(prev,s0) - d(s_end,next) - d(c,c').
    """
    m, n = tours.shape
    pos = tour_positions(tours)
    s0 = tours
    s_end = jnp.roll(tours, -(seg_len - 1), axis=-1)
    c = nn[s0]                                       # (m, n, k)
    k = c.shape[-1]
    q = jnp.take_along_axis(pos, c.reshape(m, -1), axis=1).reshape(m, n, k)
    idx = jnp.arange(n, dtype=jnp.int32)
    if n_actual is None:
        prev = jnp.roll(tours, 1, axis=-1)
        nxt = jnp.roll(tours, -seg_len, axis=-1)
        c_nxt = jnp.take_along_axis(
            tours, ((q + 1) % n).reshape(m, -1), axis=1).reshape(m, n, k)
        n_lim = n
    else:
        # padded tour: wrap within the real prefix [0, n_actual) only.
        succ = _successors(tours, n_actual)
        prev = jnp.where(idx == 0,
                         jnp.take_along_axis(
                             tours, jnp.broadcast_to(n_actual - 1, (m, 1)), 1),
                         jnp.roll(tours, 1, axis=-1))
        nxt = jnp.take_along_axis(
            tours, jnp.broadcast_to((idx + seg_len) % n_actual, (m, n)), 1)
        c_nxt = jnp.take_along_axis(
            succ, q.reshape(m, -1), axis=1).reshape(m, n, k)
        n_lim = n_actual
    delta = (
        dist[prev, nxt][..., None] + dist[s0[..., None], c]
        + dist[s_end[..., None], c_nxt]
        - dist[prev, s0][..., None] - dist[s_end, nxt][..., None]
        - dist[c, c_nxt]
    )
    p = idx[None, :, None]
    in_seg = (q >= p) & (q < p + seg_len)
    valid = (~in_seg) & (c != prev[..., None]) & (p <= n_lim - seg_len)
    if n_actual is not None:
        valid = valid & (c < n_actual)
    val, idx_sel = kref.select_move(delta.reshape(m, -1), valid.reshape(m, -1),
                                    thr=cfg.min_delta, mode=cfg.improvement)
    safe = jnp.clip(idx_sel, 0, n * k - 1)
    p_sel = (safe // k).astype(jnp.int32)
    q_sel = jnp.take_along_axis(q.reshape(m, -1), safe[:, None], axis=1)[:, 0]
    return Move(val, p_sel, q_sel)


def apply_or_opt(tours: Array, p: Array, q: Array, seg_len: int,
                 do: Array) -> Array:
    """Relocate the segment at [p, p+seg_len) to just after position q.

    Implemented as a fractional-sort-key argsort: non-segment cities keep
    their integer position as key, segment cities get keys strictly between
    q and q+1 — a stable fixed-shape formulation of splice-and-insert.
    """
    n = tours.shape[1]
    idx = jnp.arange(n, dtype=jnp.int32)[None, :]
    in_seg = (idx >= p[:, None]) & (idx < p[:, None] + seg_len)
    off = (idx - p[:, None]).astype(jnp.float32)
    key = jnp.where(in_seg,
                    q[:, None].astype(jnp.float32)
                    + (off + 1.0) / (seg_len + 1.0),
                    idx.astype(jnp.float32))
    key = jnp.where(do[:, None], key, idx.astype(jnp.float32))
    order = jnp.argsort(key, axis=1)
    return jnp.take_along_axis(tours, order, axis=1)


def or_opt_round(dist: Array, nn: Array, tours: Array,
                 cfg: LocalSearchConfig,
                 n_actual: Optional[Array] = None) -> Array:
    for seg_len in range(1, min(cfg.seg_max, tours.shape[1] - 2) + 1):
        mv = best_or_opt_move(dist, nn, tours, seg_len, cfg, n_actual)
        tours = apply_or_opt(tours, mv.i, mv.j, seg_len,
                             mv.delta < -cfg.min_delta)
    return tours


# --------------------------------------------------------------------------
# Driver + registry
# --------------------------------------------------------------------------

def _round_2opt_oropt(dist, nn, tours, cfg, n_actual=None):
    return or_opt_round(dist, nn, two_opt_round(dist, nn, tours, cfg, n_actual),
                        cfg, n_actual)


def _round_none(dist, nn, tours, cfg, n_actual=None):
    del dist, nn, cfg, n_actual
    return tours


RoundFn = Callable[..., Array]

# name -> one-improvement-round function (mirrors pheromone.STRATEGIES)
STRATEGIES: dict[str, RoundFn] = {
    "none": _round_none,
    "2opt": two_opt_round,
    "oropt": or_opt_round,
    "2opt_oropt": _round_2opt_oropt,
}


def improve(dist: Array, nn: Array, tours: Array,
            cfg: LocalSearchConfig,
            n_actual: Optional[Array] = None) -> Array:
    """Run up to ``cfg.rounds`` improvement rounds on all tours at once.

    Never worsens any tour; jit/scan/vmap/shard_map compatible (fixed
    shapes; the only data-dependent control flow is the bounded
    while_loop below, which those transforms all support).

    ``n_actual``: traced real-city count for padded tours (solver/): moves
    are restricted to the real prefix, the phantom tail is never touched.
    """
    if cfg.kind not in STRATEGIES:
        raise ValueError(
            f"unknown local-search strategy {cfg.kind!r}; "
            f"expected one of {tuple(STRATEGIES)}")
    if cfg.kind == "none" or cfg.rounds <= 0 or tours.shape[1] < 4:
        return tours
    round_fn = STRATEGIES[cfg.kind]

    # bounded while_loop instead of a fixed-length scan: once no tour
    # changed in a round the search has converged (every further round
    # would re-evaluate the full (m, n*k) move tensor for nothing).
    def cond(carry):
        _, r, changed = carry
        return (r < cfg.rounds) & changed

    def body(carry):
        t, r, _ = carry
        t2 = round_fn(dist, nn, t, cfg, n_actual)
        return t2, r + 1, jnp.any(t2 != t)

    tours, _, _ = jax.lax.while_loop(
        cond, body, (tours, jnp.int32(0), jnp.bool_(True)))
    return tours


@partial(jax.jit, static_argnames=("cfg",))
def improve_with_lengths(dist: Array, nn: Array, tours: Array,
                         cfg: LocalSearchConfig,
                         n_actual: Optional[Array] = None
                         ) -> tuple[Array, Array]:
    """improve() + recomputed closed-tour lengths (one fused program)."""
    out = improve(dist, nn, tours, cfg, n_actual)
    return out, tsp.tour_length(dist, out, n_actual)
