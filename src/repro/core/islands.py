"""Distributed ACO on the production mesh (DESIGN.md §4).

Two orthogonal, composable levels of parallelism — the paper's two stages,
lifted from the chip to the network:

1. **Island model** over the ``pod`` x ``data`` axes (Stützle '98 /
   Michel-Middendorf, the paper's §III related work): each island runs an
   independent colony; every ``exchange_every`` local iterations the islands
   (a) migrate their best tour around a ``ppermute`` ring — an immigrant
   better than the local best replaces it and deposits like an elite ant —
   and (b) optionally mix pheromone trails toward the population mean
   (``tau <- (1-lam) tau + lam mean``, lam=0 disables). Exchanges are the
   only synchronisation points: stragglers cost nothing in between
   (bounded-staleness BSP), and the exchange collective itself is a
   fixed-size (n,)-int message, independent of colony size.

2. **City-sharded colony** over the ``model`` axis, for instances whose
   pheromone matrix does not fit one device: the choice matrix, tabu mask
   and pheromone matrix are column-sharded; each shard computes a *partial
   best* next city and an ``all_gather`` of the (value, index) pairs picks
   the winner — the paper's Fig.1 tile-then-reduce scheme where a "tile" is
   a whole accelerator and the reduction runs over ICI. The deposit shard is
   a column slab computed with the one-hot-matmul kernel (no all-reduce of
   the n^2 matrix is ever needed: tours are replicated, the deposit is
   computed owner-local — communication is O(m) per step, not O(n^2)).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import aco, pheromone, quant, strategies, tsp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class IslandConfig:
    aco: aco.ACOConfig = dataclasses.field(default_factory=aco.ACOConfig)
    exchange_every: int = 8       # local iterations between exchanges
    rounds: int = 4               # number of exchange rounds
    mix_lambda: float = 0.1       # pheromone mixing toward population mean
    migrate: bool = True          # best-tour ring migration
    elite_weight: float = 1.0     # immigrant deposit scale


# --------------------------------------------------------------------------
# Island model (pod/data axes)
# --------------------------------------------------------------------------

def init_island_states(instance: tsp.TSPInstance, cfg: IslandConfig,
                       n_islands: int, seed0: int = 0) -> aco.ColonyState:
    """Stacked ColonyState with leading island axis; distinct RNG streams."""
    states = [aco.init_colony(instance, cfg.aco, seed=seed0 + i)
              for i in range(n_islands)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def _exchange(st: aco.ColonyState, problem: aco.Problem, cfg: IslandConfig,
              axis: str | tuple[str, ...],
              axis_sizes: dict[str, int]) -> aco.ColonyState:
    """Ring migration + pheromone mixing. st leaves have leading local axis 1.

    axis_sizes carries the static mesh extents (mesh.shape) — axis sizes
    must be known at trace time for the ppermute ring and the early-out.
    """
    ax = (axis,) if isinstance(axis, str) else tuple(axis)
    size = 1
    for a in ax:
        size *= axis_sizes[a]
    if size == 1:
        return st

    new_tau = st.tau
    best_tour, best_len = st.best_tour, st.best_len
    if cfg.migrate:
        # flatten multi-axis ring: successor along the last axis with carry.
        perm_axis = ax[-1]
        sz = axis_sizes[perm_axis]
        perm = [(i, (i + 1) % sz) for i in range(sz)]
        imm_tour = jax.lax.ppermute(st.best_tour, perm_axis, perm)
        imm_len = jax.lax.ppermute(st.best_len, perm_axis, perm)
        if cfg.aco.local_search != "none":
            # polish the immigrant before it competes and deposits
            # (DESIGN.md §7): the local leading axis doubles as the batch.
            imm_tour, imm_len = aco.polish_tours(problem, imm_tour, cfg.aco)
        better = imm_len < st.best_len
        best_tour = jnp.where(better, imm_tour, st.best_tour)
        best_len = jnp.where(better, imm_len, st.best_len)
        # immigrant deposits like an elite ant
        # local leading axis (1 island/device) doubles as the ant axis m=1.
        w = (cfg.elite_weight * cfg.aco.q / jnp.maximum(imm_len, 1e-9))
        dep = pheromone.deposit(st.tau.shape[-1], imm_tour, w, "scatter")
        new_tau = st.tau + jnp.where(better[..., None, None], dep, 0.0)
    if cfg.mix_lambda > 0.0:
        mean_tau = jax.lax.pmean(new_tau, ax)
        new_tau = (1 - cfg.mix_lambda) * new_tau + cfg.mix_lambda * mean_tau
    return aco.ColonyState(new_tau, best_tour, best_len, st.iteration, st.key)


def run_islands(instance: tsp.TSPInstance, cfg: IslandConfig, mesh: Mesh,
                island_axes: tuple[str, ...] = ("data",),
                state: Optional[aco.ColonyState] = None,
                checkpoint_cb=None) -> aco.ColonyState:
    """Run the island model with one island per device along island_axes.

    Any mesh axis not in island_axes must have size 1 (or be consumed by the
    sharded-colony path below). Returns the stacked island states; global
    best = argmin over the island axis.
    """
    if quant.is_quantised(cfg.aco.tau_dtype):
        from repro.kernels import ops as kops
        raise kops.UnsupportedKernelRoute(
            "the island model cannot run over a quantised pheromone store "
            f"(tau_dtype={cfg.aco.tau_dtype!r}): immigrant deposits and "
            "pmean trail mixing operate on raw fp32 tau leaves. Run "
            "tau_dtype='fp32' for islands, or use the engine/streaming "
            "routes for quantised colonies.")
    n_islands = int(np.prod([mesh.shape[a] for a in island_axes]))
    if state is None:
        state = init_island_states(instance, cfg, n_islands)
    problem = aco.make_problem(instance, cfg.aco.nn_k)

    spec = P(island_axes)
    st_specs = aco.ColonyState(
        tau=P(island_axes, None, None), best_tour=P(island_axes, None),
        best_len=spec, iteration=spec, key=P(island_axes, None))

    @partial(shard_map, mesh=mesh, in_specs=(st_specs,),
             out_specs=st_specs, check_rep=False)
    def round_fn(st: aco.ColonyState) -> aco.ColonyState:
        # local leading axis is 1 island per device: vmap over it.
        def one(st1):
            st1, _ = aco.run_scan(problem, st1, cfg.aco, cfg.exchange_every)
            return st1
        st = jax.vmap(one)(st)
        return _exchange(st, problem, cfg, island_axes,
                         {a: mesh.shape[a] for a in island_axes})

    step = jax.jit(round_fn)
    for r in range(cfg.rounds):
        state = step(state)
        if checkpoint_cb is not None:
            checkpoint_cb(state, r)
    return state


def global_best(state: aco.ColonyState) -> tuple[np.ndarray, float]:
    lens = np.asarray(state.best_len)
    i = int(np.argmin(lens))
    return np.asarray(state.best_tour[i]), float(lens[i])


# --------------------------------------------------------------------------
# City-sharded colony (model axis) — the paper's tiling at mesh level
# --------------------------------------------------------------------------

class ShardedColonyState(NamedTuple):
    tau: Array        # (n, n/S) column shard per device
    best_tour: Array  # (n,) replicated
    best_len: Array   # ()
    iteration: Array  # ()
    key: Array


def init_sharded_colony(instance: tsp.TSPInstance, cfg: aco.ACOConfig,
                        mesh: Mesh, axis: str = "model") -> ShardedColonyState:
    n = instance.n
    tau0 = aco.initial_tau(instance, cfg)
    s = mesh.shape[axis]
    assert n % s == 0, f"n={n} must divide model axis {s}"
    tau = jnp.full((n, n), tau0, jnp.float32)
    rep = NamedSharding(mesh, P())
    return ShardedColonyState(
        tau=jax.device_put(tau, NamedSharding(mesh, P(None, axis))),
        best_tour=jax.device_put(jnp.arange(n, dtype=jnp.int32), rep),
        best_len=jax.device_put(jnp.asarray(np.inf, jnp.float32), rep),
        iteration=jax.device_put(jnp.asarray(0, jnp.int32), rep),
        key=jax.device_put(jax.random.PRNGKey(cfg.seed), rep),
    )


def _sharded_construct(dist_l: Array, choice_l: Array, key: Array, m: int,
                       n: int, nl: int, axis: str, selection: str
                       ) -> tuple[Array, Array]:
    """Construct m tours with column-sharded choice matrix.

    dist_l/choice_l: (n, nl) local column slabs. Returns (tours (m,n)
    replicated, lengths (m,)).
    """
    sidx = jax.lax.axis_index(axis)
    col0 = sidx * nl
    kp, kc = jax.random.split(key)
    start = jax.random.randint(kp, (m,), 0, n, dtype=jnp.int32)  # replicated
    ants = jnp.arange(m)

    vis0 = jnp.zeros((m, nl), jnp.bool_)
    own0 = (start >= col0) & (start < col0 + nl)
    vis0 = vis0.at[ants, jnp.clip(start - col0, 0, nl - 1)].max(own0)

    def body(carry, t):
        cur, vis, lens = carry
        k = jax.random.fold_in(kc, t)
        k = jax.random.fold_in(k, sidx)          # decorrelated per shard
        w = choice_l[cur] * (~vis)               # (m, nl)
        u = jax.random.uniform(k, w.shape, w.dtype, minval=1e-6, maxval=1.0)
        v = w * u                                # iroulette partial
        pv = jnp.max(v, axis=1)                  # (m,) partial best value
        pi = jnp.argmax(v, axis=1).astype(jnp.int32) + col0
        # mesh-level reduction over shards: the paper's final argmax, as two
        # (m,)-sized all-reduces (pmax value + pmin index among the max-
        # holders) instead of an (S, m) all-gather — 16x fewer bytes and
        # bit-identical first-argmax semantics (smallest winning index).
        gmax = jax.lax.pmax(pv.astype(jnp.float32), axis)
        cand = jnp.where(pv.astype(jnp.float32) == gmax, pi,
                         jnp.int32(2**31 - 1))
        nxt = jax.lax.pmin(cand, axis)
        own = (nxt >= col0) & (nxt < col0 + nl)
        vis = vis.at[ants, jnp.clip(nxt - col0, 0, nl - 1)].max(own)
        # length contribution d[cur, nxt]: owner of nxt column adds it.
        dloc = dist_l[cur, jnp.clip(nxt - col0, 0, nl - 1)]
        lens = lens + jnp.where(own, dloc, 0.0)
        return (nxt, vis, lens), nxt

    lens0 = jnp.zeros((m,), jnp.float32)
    (last, _, lens), steps = jax.lax.scan(
        body, (start, vis0, lens0), jnp.arange(1, n))
    # closing edge last->start
    ownc = (start >= col0) & (start < col0 + nl)
    lens = lens + jnp.where(
        ownc, dist_l[last, jnp.clip(start - col0, 0, nl - 1)], 0.0)
    lens = jax.lax.psum(lens, axis)
    tours = jnp.concatenate([start[None], steps], 0).T.astype(jnp.int32)
    return tours, lens


def sharded_colony_step_fn(mesh: Mesh, n: int, cfg: aco.ACOConfig,
                           axis: str = "model", use_pallas: bool = False,
                           ants_axis: Optional[str] = None,
                           choice_dtype=jnp.float32):
    """Build the jitted city-sharded colony step for a given mesh/instance.

    ants_axis: additionally shard the ant population over this axis (the
    paper's task-level parallelism lifted to the mesh: one colony, ants split
    m/|data| per row, deposit psum'd over the rows). choice_dtype=bf16 halves
    the per-step choice-row gather traffic (the memory-bound term of the
    construction loop).
    """
    s = mesh.shape[axis]
    nl = n // s
    m = cfg.num_ants(n)
    d_ants = mesh.shape[ants_axis] if ants_axis else 1
    assert m % d_ants == 0
    m_l = m // d_ants

    dspec = P(None, axis)
    st_spec = ShardedColonyState(
        tau=dspec, best_tour=P(None), best_len=P(), iteration=P(), key=P(None))

    def step(dist_l: Array, eta_l: Array, st: ShardedColonyState):
        choice_l = strategies.choice_matrix(
            st.tau, eta_l, cfg.alpha, cfg.beta).astype(choice_dtype)
        key, k_t = jax.random.split(st.key)
        if ants_axis:
            k_t = jax.random.fold_in(k_t, jax.lax.axis_index(ants_axis))
        tours, lengths = _sharded_construct(
            dist_l, choice_l, k_t, m_l, n, nl, axis, cfg.selection)
        ib = jnp.argmin(lengths)
        it_len = lengths[ib]
        it_tour = tours[ib]
        if ants_axis:
            # global iteration-best across ant shards: tiny all-gather
            lens_all = jax.lax.all_gather(it_len, ants_axis)     # (D,)
            tours_all = jax.lax.all_gather(it_tour, ants_axis)   # (D, n)
            gb = jnp.argmin(lens_all)
            it_len = lens_all[gb]
            it_tour = tours_all[gb]
        better = it_len < st.best_len
        best_len = jnp.where(better, it_len, st.best_len)
        best_tour = jnp.where(better, it_tour, st.best_tour)
        # owner-local column-slab deposit (communication-free on the city
        # axis; psum over ant shards when the population is split).
        col0 = jax.lax.axis_index(axis) * nl
        frm = tours.ravel()
        to = jnp.roll(tours, -1, axis=-1).ravel()
        wrep = jnp.repeat(cfg.q / lengths, n)
        f2 = jnp.concatenate([frm, to])
        t2 = jnp.concatenate([to, frm]) - col0   # local column frame
        w2 = jnp.concatenate([wrep, wrep])
        t2 = jnp.where((t2 >= 0) & (t2 < nl), t2, -1)
        if use_pallas:
            from repro.kernels import pheromone_update as pu_k
            tau = pu_k.pheromone_update(st.tau, f2, t2, w2, cfg.rho,
                                        interpret=True)
            dep = tau - (1 - cfg.rho) * st.tau
        else:
            valid = t2 >= 0
            dep = jnp.zeros((n, nl), jnp.float32).at[
                jnp.where(valid, f2, 0), jnp.where(valid, t2, 0)
            ].add(jnp.where(valid, w2, 0.0))
        if ants_axis:
            dep = jax.lax.psum(dep, ants_axis)
        tau = (1 - cfg.rho) * st.tau + dep
        return ShardedColonyState(tau, best_tour, best_len,
                                  st.iteration + 1, key), it_len

    smapped = shard_map(step, mesh=mesh, in_specs=(dspec, dspec, st_spec),
                        out_specs=(st_spec, P()), check_rep=False)
    return jax.jit(smapped)


def run_sharded_colony(instance: tsp.TSPInstance, cfg: aco.ACOConfig,
                       mesh: Mesh, axis: str = "model",
                       iterations: Optional[int] = None,
                       state: Optional[ShardedColonyState] = None
                       ) -> ShardedColonyState:
    if quant.is_quantised(cfg.tau_dtype):
        from repro.kernels import ops as kops
        raise kops.UnsupportedKernelRoute(
            "the city-sharded colony cannot run over a quantised pheromone "
            f"store (tau_dtype={cfg.tau_dtype!r}): tau column slabs are raw "
            "fp32 per-device shards. Run tau_dtype='fp32' on this route.")
    n = instance.n
    d = jnp.asarray(instance.distances())
    eta = tsp.heuristic_matrix(d)
    sh = NamedSharding(mesh, P(None, axis))
    d = jax.device_put(d, sh)
    eta = jax.device_put(eta, sh)
    if state is None:
        state = init_sharded_colony(instance, cfg, mesh, axis)
    step = sharded_colony_step_fn(mesh, n, cfg, axis)
    for _ in range(iterations or cfg.iterations):
        state, _ = step(d, eta, state)
    return state
