"""Probabilistic next-city selection rules.

Three selection semantics, matching DESIGN.md §2:

- ``roulette``   exact categorical sampling by inverse-CDF (cumsum +
                 searchsorted). This is the sequential algorithm's semantics
                 (Stützle's ANSI-C code) and the paper's task-parallel baseline.
- ``iroulette``  the paper's data-parallel scheme (Fig. 1): every city
                 multiplies its choice value by an independent U(0,1] draw and
                 an argmax-reduction picks the winner ("independent roulette").
                 Not identical in distribution to roulette, but this is what
                 the paper ships; kept for fidelity.
- ``gumbel``     exact categorical sampling via the Gumbel-max trick —
                 argmax(log w + G). Same data-parallel shape as iroulette but
                 exact; the TPU gets this for free (beyond-paper default).

All functions are batched: weights (..., n) -> choice (...,) int32. Invalid
cities must already carry weight 0 (mask applied by the caller).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_NEG_INF = -1e30


def roulette(key: Array, weights: Array) -> Array:
    """Exact inverse-CDF sampling. weights (..., n) >= 0, not normalised."""
    cdf = jnp.cumsum(weights, axis=-1)
    total = cdf[..., -1:]
    u = jax.random.uniform(key, weights.shape[:-1] + (1,), weights.dtype)
    r = u * total
    # searchsorted per row: count of cdf entries strictly below r.
    idx = (cdf < r).sum(axis=-1)
    n = weights.shape[-1]
    return jnp.clip(idx, 0, n - 1).astype(jnp.int32)


def iroulette(key: Array, weights: Array) -> Array:
    """Paper's independent-roulette: argmax(w * U). Zero weights never win
    unless all weights are zero (then argmax returns 0 deterministically)."""
    u = jax.random.uniform(
        key, weights.shape, weights.dtype, minval=1e-6, maxval=1.0
    )
    return jnp.argmax(weights * u, axis=-1).astype(jnp.int32)


def gumbel(key: Array, weights: Array) -> Array:
    """Exact categorical via Gumbel-max on log-weights; zeros masked to -inf."""
    logw = jnp.where(weights > 0, jnp.log(jnp.maximum(weights, 1e-38)), _NEG_INF)
    g = jax.random.gumbel(key, weights.shape, weights.dtype)
    return jnp.argmax(logw + g, axis=-1).astype(jnp.int32)


def greedy(key: Array, weights: Array) -> Array:
    """Deterministic argmax (ACS exploitation step / NN-list fallback)."""
    del key
    return jnp.argmax(weights, axis=-1).astype(jnp.int32)


SELECTORS = {
    "roulette": roulette,
    "iroulette": iroulette,
    "gumbel": gumbel,
    "greedy": greedy,
}


def select(name: str, key: Array, weights: Array) -> Array:
    return SELECTORS[name](key, weights)
