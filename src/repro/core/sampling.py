"""Probabilistic next-city selection rules.

Three selection semantics, matching DESIGN.md §2:

- ``roulette``   exact categorical sampling by inverse-CDF (cumsum +
                 searchsorted). This is the sequential algorithm's semantics
                 (Stützle's ANSI-C code) and the paper's task-parallel baseline.
- ``iroulette``  the paper's data-parallel scheme (Fig. 1): every city
                 multiplies its choice value by an independent U(0,1] draw and
                 an argmax-reduction picks the winner ("independent roulette").
                 Not identical in distribution to roulette, but this is what
                 the paper ships; kept for fidelity.
- ``gumbel``     exact categorical sampling via the Gumbel-max trick —
                 argmax(log w + G). Same data-parallel shape as iroulette but
                 exact; the TPU gets this for free (beyond-paper default).

All functions are batched: weights (..., n) -> choice (...,) int32. Invalid
cities must already carry weight 0 (mask applied by the caller).

Draw modes (DESIGN.md §16): the default "packed" draws use
``jax.random.uniform(key, shape)``, whose threefry counters run over the
*flat* index — bits at (ant, city) depend on the array width, so the same
colony padded into a wider bucket draws different randomness.  "counter"
mode derives each element's bits from an explicit (ant, city) counter
(``counter_uniform``/``counter_gumbel``): the draw at a real (ant, city)
pair is bitwise identical in every bucket width, which is what makes the
neighbour-bucket route of the AOT program cache (solver/programs.py) exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

_NEG_INF = -1e30

# (ant, city) -> threefry counter stride: counters are i * 2^16 + j, so the
# mapping is collision-free for any bucket width n <= 65536 (beyond paper
# scale) and — unlike the packed flat index i * n + j — independent of n.
COUNTER_STRIDE = 1 << 16


def _key_data(key: Array) -> Array:
    """Raw (2,) uint32 words of a PRNG key (typed or raw-array form)."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(key)
    return key


def counter_bits(key: Array, shape: tuple) -> Array:
    """Width-invariant uint32 random bits for a 2-D (m, n) draw.

    Element (i, j) gets ``threefry2x32(key, i * COUNTER_STRIDE + j)`` —
    the bits depend only on the key and the (ant, city) pair, never on the
    array width, so ``counter_bits(key, (m, n))[:, :n0]`` equals
    ``counter_bits(key, (m, n0))`` bitwise for any n >= n0.
    """
    m, n = shape
    if n > COUNTER_STRIDE:
        raise ValueError(f"counter draw width {n} > {COUNTER_STRIDE}")
    from jax._src import prng as _prng
    kd = _key_data(key)
    rows = jnp.arange(m, dtype=jnp.uint32) * jnp.uint32(COUNTER_STRIDE)
    ctr = rows[:, None] + jnp.arange(n, dtype=jnp.uint32)[None, :]
    k0 = jnp.broadcast_to(kd[0], shape)
    k1 = jnp.broadcast_to(kd[1], shape)
    out = _prng.threefry2x32_p.bind(k0, k1, ctr,
                                    jnp.zeros(shape, jnp.uint32))
    return out[0]


def _uniform_from_bits(bits: Array, minval: float, maxval: float) -> Array:
    """bits -> U[minval, maxval) float32, the exact jax.random.uniform
    mantissa construction (so values share its distribution and edge
    behaviour: 9-bit shift into [1, 2), subtract 1, scale, clamp low)."""
    flo = jax.lax.bitcast_convert_type(
        (bits >> np.uint32(9)) | np.uint32(0x3F800000), jnp.float32)
    flo = flo - np.float32(1.0)
    return jnp.maximum(jnp.float32(minval),
                       flo * (maxval - minval) + minval)


def counter_uniform(key: Array, shape: tuple, minval: float = 0.0,
                    maxval: float = 1.0) -> Array:
    """Width-invariant U[minval, maxval) draw for 2-D (m, n) shapes."""
    return _uniform_from_bits(counter_bits(key, shape), minval, maxval)


def counter_gumbel(key: Array, shape: tuple) -> Array:
    """Width-invariant standard Gumbel draw (the jax.random.gumbel map
    -log(-log(U[tiny, 1))) over counter-mode uniforms)."""
    tiny = float(np.finfo(np.float32).tiny)
    u = counter_uniform(key, shape, minval=tiny, maxval=1.0)
    return -jnp.log(-jnp.log(u))


def roulette(key: Array, weights: Array) -> Array:
    """Exact inverse-CDF sampling. weights (..., n) >= 0, not normalised."""
    cdf = jnp.cumsum(weights, axis=-1)
    total = cdf[..., -1:]
    u = jax.random.uniform(key, weights.shape[:-1] + (1,), weights.dtype)
    r = u * total
    # searchsorted per row: count of cdf entries strictly below r.
    idx = (cdf < r).sum(axis=-1)
    n = weights.shape[-1]
    return jnp.clip(idx, 0, n - 1).astype(jnp.int32)


def iroulette(key: Array, weights: Array) -> Array:
    """Paper's independent-roulette: argmax(w * U). Zero weights never win
    unless all weights are zero (then argmax returns 0 deterministically)."""
    u = jax.random.uniform(
        key, weights.shape, weights.dtype, minval=1e-6, maxval=1.0
    )
    return jnp.argmax(weights * u, axis=-1).astype(jnp.int32)


def gumbel(key: Array, weights: Array) -> Array:
    """Exact categorical via Gumbel-max on log-weights; zeros masked to -inf."""
    logw = jnp.where(weights > 0, jnp.log(jnp.maximum(weights, 1e-38)), _NEG_INF)
    g = jax.random.gumbel(key, weights.shape, weights.dtype)
    return jnp.argmax(logw + g, axis=-1).astype(jnp.int32)


def greedy(key: Array, weights: Array) -> Array:
    """Deterministic argmax (ACS exploitation step / NN-list fallback)."""
    del key
    return jnp.argmax(weights, axis=-1).astype(jnp.int32)


def iroulette_counter(key: Array, weights: Array) -> Array:
    """``iroulette`` with counter-mode (width-invariant) uniforms."""
    u = counter_uniform(key, weights.shape, minval=1e-6, maxval=1.0)
    return jnp.argmax(weights * u, axis=-1).astype(jnp.int32)


def gumbel_counter(key: Array, weights: Array) -> Array:
    """``gumbel`` with counter-mode (width-invariant) Gumbel noise."""
    logw = jnp.where(weights > 0, jnp.log(jnp.maximum(weights, 1e-38)),
                     _NEG_INF)
    g = counter_gumbel(key, weights.shape)
    return jnp.argmax(logw + g, axis=-1).astype(jnp.int32)


SELECTORS = {
    "roulette": roulette,
    "iroulette": iroulette,
    "gumbel": gumbel,
    "greedy": greedy,
}

# Counter-mode selector table: ``roulette`` draws one U per *ant* — shape
# (m, 1), already width-invariant given m — and ``greedy`` draws nothing,
# so both map to themselves; only the per-(ant, city) draws get rewired.
SELECTORS_COUNTER = {
    "roulette": roulette,
    "iroulette": iroulette_counter,
    "gumbel": gumbel_counter,
    "greedy": greedy,
}

DRAW_MODES = ("packed", "counter")


def get_selector(name: str, draw_mode: str = "packed"):
    """Selector fn for (selection, draw_mode); KeyError on unknown name."""
    if draw_mode not in DRAW_MODES:
        raise ValueError(f"unknown draw_mode {draw_mode!r}; "
                         f"supported: {', '.join(DRAW_MODES)}")
    table = SELECTORS_COUNTER if draw_mode == "counter" else SELECTORS
    return table[name]


def select(name: str, key: Array, weights: Array,
           draw_mode: str = "packed") -> Array:
    return get_selector(name, draw_mode)(key, weights)
