"""Pheromone-update strategies (paper §IV.B, Tables III/IV).

Strategy ladder, mirroring the paper's kernel versions:

- ``scatter``     the TPU analogue of the paper's winning *atomic* version:
                  XLA scatter-add of 1/C^k along each ant's tour edges.
                  (TPU has no atomics; XLA serialises colliding updates in a
                  sorted scatter — semantically identical to atomicAdd.)
- ``reduction``   the paper's Instruction & Thread *Reduction* version:
                  symmetric TSP => canonicalise each edge to (lo, hi) and
                  scatter only the upper triangle, half the update work, then
                  mirror.
- ``s2g``         honest *scatter-to-gather* (paper Fig. 3): every pheromone
                  cell scans every tour edge — O(n^4) work for m = n. Kept
                  deliberately faithful so the paper's Table III slow-down
                  scaling (claim C4) is reproducible.
- ``s2g_tiled``   scatter-to-gather with tile-blocked membership tests
                  (paper's 'Tiling' version, tile = theta).
- ``onehot``      TPU-native adaptation (DESIGN.md §2): deposit as a one-hot
                  matmul D = F^T (w * T) over edge chunks. Same pure-gather
                  memory pattern as s2g, but the membership test becomes MXU
                  work. The Pallas kernel (kernels/pheromone_update.py)
                  builds the one-hots in VMEM on the fly.

All strategies produce identical tau (up to float associativity); asserted in
tests/test_pheromone.py.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def evaporate(tau: Array, rho: float) -> Array:
    """Eq. 2: tau <- (1 - rho) tau."""
    return (1.0 - rho) * tau


def tour_edges(tours: Array,
               n_actual: Optional[Array] = None) -> tuple[Array, Array]:
    """Directed edge endpoints (m, n) for closed tours.

    With ``n_actual`` (traced scalar; padded instances, DESIGN.md §8) the
    closing edge wraps at position n_actual-1 back to position 0; the
    phantom-tail positions still produce (phantom, phantom) index pairs but
    the masked deposit functions below give them zero weight.
    """
    t = jnp.roll(tours, -1, axis=-1)
    if n_actual is not None:
        idx = jnp.arange(tours.shape[-1], dtype=jnp.int32)
        t = jnp.where(idx == n_actual - 1, tours[..., :1], t)
    return tours, t


def edge_weights(tours: Array, w: Array,
                 n_actual: Optional[Array] = None) -> Array:
    """(m*n,) per-edge deposit weights; phantom-tail edges masked to 0.

    Public alongside ``tour_edges``: the kernel deposit wrapper
    (kernels/ops.pheromone_update) builds its edge stream with the same
    pair, so the kernel and pure-JAX routes share one edge semantics.
    """
    ns = tours.shape[-1]
    wrep = jnp.broadcast_to(w[:, None], (w.shape[0], ns))
    if n_actual is not None:
        idx = jnp.arange(ns, dtype=jnp.int32)
        wrep = jnp.where(idx[None, :] < n_actual, wrep, 0.0)
    return wrep.ravel()


def deposit_scatter(n: int, tours: Array, w: Array, symmetric: bool = True,
                    n_actual: Optional[Array] = None) -> Array:
    """Atomic-analogue scatter-add (paper versions 1/2)."""
    f, t = tour_edges(tours, n_actual)
    wrep = edge_weights(tours, w, n_actual)
    d = jnp.zeros((n, n), jnp.float32).at[f.ravel(), t.ravel()].add(wrep)
    if symmetric:
        d = d + d.T
    return d


def deposit_reduction(n: int, tours: Array, w: Array,
                      n_actual: Optional[Array] = None) -> Array:
    """Paper's Reduction version: half the scatters via edge canonicalisation."""
    f, t = tour_edges(tours, n_actual)
    lo = jnp.minimum(f, t)
    hi = jnp.maximum(f, t)
    wrep = edge_weights(tours, w, n_actual)
    upper = jnp.zeros((n, n), jnp.float32).at[lo.ravel(), hi.ravel()].add(wrep)
    return upper + upper.T


@partial(jax.jit, static_argnames=("n", "row_tile", "col_tile"))
def deposit_s2g(n: int, tours: Array, w: Array, row_tile: int = 0,
                col_tile: int = 0, n_actual: Optional[Array] = None) -> Array:
    """Scatter-to-gather: cell (i,j) gathers over ALL m*n edges (paper Fig. 3).

    row_tile/col_tile = 0 means untiled semantics (single tile). The tiled
    variant is the paper's 'Scatter to Gather + Tiling'; tiles bound the
    VMEM-resident membership masks exactly like the paper's shared-memory
    tiles. Work is O(n^2 * m * n) regardless of tiling — that is the point.

    Mask-aware for padded tours: phantom-tail edges carry weight 0 so their
    (phantom, phantom) membership hits contribute nothing, and the closing
    edge wraps at position n_actual-1 (DESIGN.md §8).
    """
    f, t = tour_edges(tours, n_actual)
    m, ns = f.shape
    bi = row_tile or min(n, 64)
    bj = col_tile or min(n, 64)
    # pad n up to multiples
    ni = -(-n // bi) * bi
    nj = -(-n // bj) * bj
    fw = (f.ravel(), edge_weights(tours, w, n_actual))
    tr = t.ravel()

    def row_block(i0):
        rows = i0 + jnp.arange(bi)
        mi = (fw[0][None, :] == rows[:, None]).astype(jnp.float32)  # (bi, E)
        mi = mi * fw[1][None, :]

        def col_block(j0):
            cols = j0 + jnp.arange(bj)
            mj = (tr[None, :] == cols[:, None]).astype(jnp.float32)  # (bj, E)
            return mi @ mj.T                                          # (bi, bj)

        blocks = jax.lax.map(col_block, jnp.arange(0, nj, bj))       # (k, bi, bj)
        return blocks.transpose(1, 0, 2).reshape(bi, nj)

    rows = jax.lax.map(row_block, jnp.arange(0, ni, bi))   # (ni/bi, bi, nj)
    d = rows.reshape(ni, nj)[:n, :n]
    return d + d.T


@partial(jax.jit, static_argnames=("n", "chunk"))
def deposit_onehot(n: int, tours: Array, w: Array, chunk: int = 8,
                   n_actual: Optional[Array] = None) -> Array:
    """TPU-native deposit: D = F^T (w*T) accumulated over ant chunks.

    F/T are (chunk*ns, n) one-hot matrices, never larger than one chunk.
    Mask-aware for padded tours: the per-edge weight matrix zeroes the
    phantom tail and the closing edge wraps at position n_actual-1.
    """
    f, t = tour_edges(tours, n_actual)
    m, ns = f.shape
    we = edge_weights(tours, w, n_actual).reshape(m, ns)
    c = min(chunk, m)
    pad = (-m) % c
    if pad:
        f = jnp.concatenate([f, jnp.zeros((pad, ns), f.dtype)], 0)
        t = jnp.concatenate([t, jnp.zeros((pad, ns), t.dtype)], 0)
        we = jnp.concatenate([we, jnp.zeros((pad, ns), we.dtype)], 0)
    nchunks = f.shape[0] // c

    def body(acc, i):
        fs = jax.lax.dynamic_slice_in_dim(f, i * c, c).ravel()
        ts = jax.lax.dynamic_slice_in_dim(t, i * c, c).ravel()
        ws = jax.lax.dynamic_slice_in_dim(we, i * c, c).ravel()
        F = jax.nn.one_hot(fs, n, dtype=jnp.float32)
        T = jax.nn.one_hot(ts, n, dtype=jnp.float32) * ws[:, None]
        return acc + F.T @ T, None

    d0 = jnp.zeros((n, n), jnp.float32)
    d, _ = jax.lax.scan(body, d0, jnp.arange(nchunks))
    return d + d.T


STRATEGIES = ("scatter", "reduction", "s2g", "s2g_tiled", "onehot")


def deposit(n: int, tours: Array, w: Array, strategy: str = "scatter",
            tile: int = 64, n_actual: Optional[Array] = None) -> Array:
    if strategy == "scatter":
        return deposit_scatter(n, tours, w, n_actual=n_actual)
    if strategy == "reduction":
        return deposit_reduction(n, tours, w, n_actual=n_actual)
    if strategy == "s2g":
        return deposit_s2g(n, tours, w, 0, 0, n_actual)
    if strategy == "s2g_tiled":
        return deposit_s2g(n, tours, w, tile, tile, n_actual)
    if strategy == "onehot":
        return deposit_onehot(n, tours, w, n_actual=n_actual)
    raise ValueError(f"unknown deposit strategy {strategy}")


def update(tau: Array, tours: Array, w: Array, rho: float,
           strategy: str = "scatter", tile: int = 64,
           n_actual: Optional[Array] = None) -> Array:
    """Full pheromone update: evaporation (eq. 2) + deposit (eq. 3/4)."""
    n = tau.shape[0]
    return evaporate(tau, rho) + deposit(n, tours, w, strategy, tile, n_actual)


def local_update_acs(tau: Array, frm: Array, to: Array, xi: float,
                     tau0: float, w: Optional[Array] = None) -> Array:
    """ACS local pheromone rule on the just-crossed edges (both directions).

    The sequential rule tau <- (1-xi) tau + xi tau0 is applied once per
    crossing.  It is a contraction toward tau0, so c applications compose to
    the closed form tau <- (1-xi)^c tau + (1 - (1-xi)^c) tau0 *independent
    of order* — which is what we compute: per-edge crossing counts via a
    deterministic scatter-add, then the closed form.  (A scatter-``set``
    with duplicate edge indices — multiple ants crossing the same edge —
    has unspecified winner order and made the result nondeterministic.)

    ``w``: optional per-edge crossing multiplicity (phantom-tail edges of
    padded tours pass 0 so they contribute no decay); defaults to 1.
    """
    n = tau.shape[0]
    ones = jnp.ones(frm.shape, tau.dtype) if w is None else w.astype(tau.dtype)
    counts = jnp.zeros((n, n), tau.dtype).at[frm, to].add(ones)
    counts = counts + counts.T               # symmetric: both directions
    factor = jnp.power(jnp.asarray(1.0 - xi, tau.dtype), counts)
    return factor * tau + (1.0 - factor) * tau0
