"""The ACO engine: Ant System (paper's subject) plus MMAS / ACS variants.

State is a pytree (``ColonyState``) so that one colony step jits cleanly,
scans across iterations, shards across mesh axes (islands.py) and round-trips
through checkpoints (checkpoint/).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import localsearch, pheromone, quant, strategies, tsp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ACOConfig:
    # Paper/Dorigo-Stützle recommended defaults.
    alpha: float = 1.0
    beta: float = 2.0
    rho: float = 0.5
    q: float = 1.0                 # deposit numerator (1/C^k scaled by q)
    m: Optional[int] = None        # ants; None => m = n (paper §V)
    variant: str = "as"            # as | mmas | acs
    construction: str = "data_parallel"
    selection: str = "iroulette"   # iroulette (paper) | gumbel (exact) | roulette
    # Per-(ant, city) randomness derivation (core/sampling.py): "packed"
    # keeps the historical flat-counter threefry draws; "counter" derives
    # each element's bits from an explicit (ant, city) counter, making the
    # draws invariant to the padded bucket width — the exactness basis of
    # the AOT program cache's neighbour-bucket route (DESIGN.md §16).
    draw_mode: str = "packed"      # packed | counter
    nn_k: int = 30                 # NN-list length (paper uses 30)
    deposit: str = "scatter"       # pheromone strategy (see pheromone.py)
    deposit_tile: int = 64
    iterations: int = 100
    seed: int = 0
    use_pallas: bool = False       # route choice/tour/deposit through kernels/
    # Local search (DESIGN.md §7): polish constructed tours before deposit.
    local_search: str = "none"     # localsearch.STRATEGIES key
    ls_every: int = 1              # apply every k-th iteration
    ls_tours: str = "all"          # all | iteration_best
    ls_rounds: int = 24            # bounded improvement rounds per application
    ls_improvement: str = "best"   # best | first
    ls_seg_max: int = 3            # Or-opt max segment length
    # MMAS
    mmas_best: str = "iteration"   # iteration | global
    # ACS
    q0: float = 0.9
    xi: float = 0.1
    # Sparse/paged representation (repro.sparse, DESIGN.md §12): O(n·k)
    # candidate-edge storage instead of dense (n, n) tensors.
    sparse: bool = False
    sparse_k: int = 32             # candidate-list width of the sparse pages
    sparse_overflow: int = 4       # off-list adoption slots per city
    partial_window: int = 64       # Partial-ACO rebuild window (construction="partial")
    # Quantised resident pheromone (core/quant.py, DESIGN.md §15): tau is
    # held as a low-precision QuantTau payload (+ per-row scales for int8)
    # and dequantised to a transient fp32 tensor for each step's compute;
    # the Pallas selection kernels dequantise tile-by-tile instead and
    # never materialise the fp32 matrix.  "fp32" keeps today's raw Array
    # leaf — bitwise-identical routes, unchanged pytree structure.
    tau_dtype: str = "fp32"        # fp32 | bf16 | int8
    tau_round: str = "stochastic"  # quantise-on-store rounding | "nearest"
    tau_compensation: bool = False  # carry fp32 error-feedback residual
    # In-jit telemetry (repro.obs, DESIGN.md §13): when True, colony_step /
    # sparse_colony_step additionally return an obs.StepMetrics pytree of
    # per-iteration convergence scalars, and engine.run_batch carries one
    # row per instance next to the ColonyState.  Statically gated and
    # bitwise-neutral: tours/lengths/tau/keys are identical either way.
    metrics: bool = False

    def num_ants(self, n: int) -> int:
        return self.m if self.m is not None else n


class ColonyState(NamedTuple):
    tau: "Array | quant.QuantTau"  # (n, n) pheromone (QuantTau if quantised)
    best_tour: Array      # (n,) int32
    best_len: Array       # () float32
    iteration: Array      # () int32
    key: Array            # PRNG key


class Hyper(NamedTuple):
    """Per-instance ACO hyperparameters as traced scalar operands.

    When attached to ``Problem.hyper`` these *override* the static
    ``ACOConfig`` fields of the same name inside ``colony_step``, and —
    because they are operands, per-instance under vmap — one compiled
    batched program (solver/engine.run_batch, solver/streaming) can mix
    tuning profiles across slots.  Exponentiation then takes the generic
    ``x ** p`` route instead of the static integer-folding fast path, so
    numerics are comparable only *within* the operand mode: batched ==
    solo holds bitwise when both carry a Hyper (tests/test_solver.py).
    """
    alpha: Array          # () float32  choice exponent on tau
    beta: Array           # () float32  choice exponent on eta
    rho: Array            # () float32  evaporation rate
    q: Array              # () float32  deposit numerator

    @classmethod
    def make(cls, cfg: "ACOConfig", alpha: Optional[float] = None,
             beta: Optional[float] = None, rho: Optional[float] = None,
             q: Optional[float] = None) -> "Hyper":
        """Profile from a config plus any per-field overrides."""
        def pick(v, d):
            return jnp.float32(d if v is None else v)
        return cls(pick(alpha, cfg.alpha), pick(beta, cfg.beta),
                   pick(rho, cfg.rho), pick(q, cfg.q))


class Problem(NamedTuple):
    """Device-resident constants for one TSP instance.

    ``n_actual`` is None for ordinary instances.  For padded instances
    (solver/batch.py: phantom cities at inf distance, eta exactly 0) it is
    the scalar count of real cities — a traced operand, per-instance under
    vmap — and flips colony_step into mask-aware mode (DESIGN.md §8).

    ``hyper`` is None for ordinary instances (hyperparameters come from the
    static ACOConfig); when set, its per-instance alpha/beta/rho/q operands
    take precedence (DESIGN.md §9).
    """
    dist: Array           # (n, n) float32
    eta: Array            # (n, n) float32  (1/d)
    nn: Array             # (n, k) int32
    n_actual: Optional[Array] = None   # () int32, or None (unpadded)
    hyper: Optional[Hyper] = None      # per-instance overrides, or None


def make_problem(instance: tsp.TSPInstance, nn_k: int = 30) -> Problem:
    dist = jnp.asarray(instance.distances())
    eta = tsp.heuristic_matrix(dist)
    nn = tsp.nn_lists(dist, min(nn_k, instance.n - 1))
    return Problem(dist, eta, nn)


def initial_tau(instance: tsp.TSPInstance, cfg: ACOConfig,
                rho: Optional[float] = None) -> float:
    """tau0 = m / C_nn (AS), 1/(rho C_nn) (MMAS), 1/(n C_nn) (ACS).

    ``rho`` overrides cfg.rho (per-instance Hyper profiles: MMAS tau0
    depends on the evaporation rate, so a slot's initial trail must match
    the profile it will run under).
    """
    d = instance.distances()
    _, c_nn = tsp.nearest_neighbour_tour(d)
    n = instance.n
    m = cfg.num_ants(n)
    if cfg.variant == "mmas":
        return 1.0 / ((cfg.rho if rho is None else rho) * c_nn)
    if cfg.variant == "acs":
        return 1.0 / (n * c_nn)
    return m / c_nn


def make_tau(tau_f32: Array, cfg: ACOConfig) -> "Array | quant.QuantTau":
    """Initial tau in the config's resident representation: raw fp32 (the
    bitwise-stable default) or a deterministically-rounded QuantTau.  Used
    by every init path (solo, engine slot stacks, streaming refill
    surgery) so a refilled slot starts from exactly what a solo quantised
    run starts from."""
    if not quant.is_quantised(cfg.tau_dtype):
        return tau_f32
    quant.validate_tau_dtype(cfg.tau_dtype, cfg.tau_round)
    return quant.quantise(tau_f32, cfg.tau_dtype,
                          compensation=cfg.tau_compensation)


def init_colony(instance: tsp.TSPInstance, cfg: ACOConfig,
                seed: Optional[int] = None) -> ColonyState:
    n = instance.n
    tau0 = initial_tau(instance, cfg)
    key = jax.random.PRNGKey(cfg.seed if seed is None else seed)
    return ColonyState(
        tau=make_tau(jnp.full((n, n), tau0, jnp.float32), cfg),
        best_tour=jnp.arange(n, dtype=jnp.int32),
        best_len=jnp.asarray(np.float32(np.inf)),
        iteration=jnp.asarray(0, jnp.int32),
        key=key,
    )


def _choice(tau: Array, eta: Array, cfg: ACOConfig, alpha, beta,
            n_actual: Optional[Array] = None) -> Array:
    if cfg.use_pallas:
        # alpha/beta are the hyper-resolved values; on the kernel route
        # check_kernel_route has already guaranteed they are the static
        # config floats (traced Hyper exponents are rejected upstream).
        from repro.kernels import ops as kops
        return kops.choice_info(tau, eta, alpha, beta, n_actual)
    return strategies.choice_matrix(tau, eta, alpha, beta)


def ls_config(cfg: ACOConfig) -> localsearch.LocalSearchConfig:
    """Derive the LocalSearchConfig embedded in an ACOConfig."""
    return localsearch.LocalSearchConfig(
        kind=cfg.local_search, rounds=cfg.ls_rounds,
        improvement=cfg.ls_improvement, seg_max=cfg.ls_seg_max,
        use_pallas=cfg.use_pallas)


def polish_tours(problem: Problem, tours: Array,
                 cfg: ACOConfig) -> tuple[Array, Array]:
    """Local-search-improve (m, n) tours; returns (tours, lengths).

    Shared by colony_step (below) and the island exchange (islands.py),
    which polishes migrated elite tours before they deposit.  Mask-aware
    when problem.n_actual is set (padded instances).
    """
    return localsearch.improve_with_lengths(
        problem.dist, problem.nn, tours, ls_config(cfg), problem.n_actual)


def _apply_local_search(problem: Problem, res: strategies.TourResult,
                        iteration: Array, cfg: ACOConfig
                        ) -> strategies.TourResult:
    """Polish constructed tours per cfg.ls_tours, every cfg.ls_every iters.

    The ls_every gate is a lax.cond on the traced iteration counter: it
    skips the work on a single colony, but under vmap (the island model
    batches colony_step over islands) cond lowers to select and both
    branches run — there ls_every>1 only changes *which* iterations'
    results are kept, not the compute.  The while_loop early-exit in
    localsearch.improve keeps the dead branch cheap (converged tours exit
    after one evaluation round).
    """
    if cfg.ls_tours not in ("all", "iteration_best"):
        raise ValueError(f"unknown ls_tours {cfg.ls_tours!r}")

    def run(args):
        tours, lengths = args
        if cfg.ls_tours == "iteration_best":
            ib = jnp.argmin(lengths)
            pol, pol_len = polish_tours(problem, tours[ib][None, :], cfg)
            return tours.at[ib].set(pol[0]), lengths.at[ib].set(pol_len[0])
        return polish_tours(problem, tours, cfg)

    if cfg.ls_every <= 1:
        tours, lengths = run((res.tours, res.lengths))
    else:
        tours, lengths = jax.lax.cond(
            iteration % cfg.ls_every == 0, run, lambda args: args,
            (res.tours, res.lengths))
    return strategies.TourResult(tours, lengths)


@partial(jax.jit, static_argnames=("cfg",))
def colony_step(problem: Problem, state: ColonyState,
                cfg: ACOConfig) -> tuple:
    """One full ACO iteration: construct m tours, update pheromone, track best.

    Returns (new_state, iteration_best_length); with ``cfg.metrics`` set,
    (new_state, iteration_best_length, obs.StepMetrics).  The metrics are
    read-only reductions over intermediates this step computes anyway — no
    extra PRNG draws, no reordering — so the state trajectory is bitwise
    identical either way (tests/test_obs.py).
    """
    n = problem.dist.shape[0]
    m = cfg.num_ants(n)
    n_act = problem.n_actual           # None, or traced () int32 (padded)
    h = problem.hyper                  # None, or traced per-instance Hyper
    quantised = quant.is_quantised(cfg.tau_dtype)
    if cfg.use_pallas:
        # Masked (padded) instances are kernel-supported; per-instance
        # Hyper operands are not (static kernel exponents) — one typed
        # rejection point for the whole kernel route (DESIGN.md §10).
        from repro.kernels import ops as kops
        kops.check_kernel_route(masked=n_act is not None,
                                hyper=h is not None,
                                tau_dtype=cfg.tau_dtype)
    elif quantised:
        # Pure-JAX quantised route still goes through the single rejection
        # point: quantised x per-instance Hyper is unsupported everywhere.
        from repro.kernels import ops as kops
        kops.check_kernel_route(hyper=h is not None, tau_dtype=cfg.tau_dtype)
    alpha = cfg.alpha if h is None else h.alpha
    beta = cfg.beta if h is None else h.beta
    rho = cfg.rho if h is None else h.rho
    q = cfg.q if h is None else h.q
    if quantised:
        # One extra split feeds quantise-on-store; the fp32 branch keeps
        # today's two-way split, so its key trajectory is untouched.
        key, k_tour, k_q = jax.random.split(state.key, 3)
    else:
        key, k_tour = jax.random.split(state.key)
        k_q = None
    # Transient fp32 view for this step's compute (identity for fp32).
    tau_full = quant.dequantise(state.tau) if quantised else state.tau

    method = cfg.construction
    if cfg.use_pallas and method == "data_parallel":
        # kernels/fused_select: the whole construction step (gather,
        # weighting, masking, selection) is one kernel — no (n, n) choice
        # precompute on this route at all.
        method = "fused"

    tau_c, tau_scale = tau_full, None
    if method == "fused":
        choice_info = jnp.zeros((1, 1), jnp.float32)   # unused by the step
        if quantised:
            # The fused kernel dequantises tile-by-tile in its epilogue:
            # hand it the resident payload (+ per-row scales for int8)
            # instead of a materialised fp32 matrix.
            tau_c = state.tau.q
            tau_scale = state.tau.scale if cfg.tau_dtype == "int8" else None
    else:
        choice_info = _choice(tau_full, problem.eta, cfg, alpha, beta,
                              n_act)

    res = strategies.construct_tours(
        k_tour, problem.dist, choice_info, m,
        method=method, selection=cfg.selection,
        nn=problem.nn, tau=tau_c, eta=problem.eta,
        alpha=alpha, beta=beta, n_actual=n_act,
        tau_scale=tau_scale, draw_mode=cfg.draw_mode,
    )

    pre_ls_lengths = None
    if cfg.local_search != "none":
        # improved tours drive the deposit: LS runs before best-tracking
        # and before the pheromone update (DESIGN.md §7).
        if cfg.metrics:
            pre_ls_lengths = res.lengths    # acceptance-rate baseline
        res = _apply_local_search(problem, res, state.iteration, cfg)

    it_best_idx = jnp.argmin(res.lengths)
    it_best_len = res.lengths[it_best_idx]
    it_best_tour = res.tours[it_best_idx]

    improved = it_best_len < state.best_len
    best_len = jnp.where(improved, it_best_len, state.best_len)
    best_tour = jnp.where(improved, it_best_tour, state.best_tour)

    if cfg.variant == "as":
        dep_tours, dep_w = res.tours, q / res.lengths
    elif cfg.variant == "mmas":
        if cfg.mmas_best == "global":
            dep_tours = best_tour[None, :]
            dep_w = (q / best_len)[None]
        else:
            dep_tours = it_best_tour[None, :]
            dep_w = (q / it_best_len)[None]
    elif cfg.variant == "acs":
        dep_tours = best_tour[None, :]
        dep_w = (rho * q / best_len)[None]
    else:
        raise ValueError(f"unknown variant {cfg.variant}")

    if cfg.use_pallas:
        from repro.kernels import ops as kops
        tau = kops.pheromone_update(tau_full, dep_tours, dep_w, rho,
                                    n_actual=n_act)
    else:
        tau = pheromone.update(tau_full, dep_tours, dep_w, rho,
                               strategy=cfg.deposit, tile=cfg.deposit_tile,
                               n_actual=n_act)

    # MMAS/ACS normalisations use the real city count of padded instances.
    n_eff = n if n_act is None else n_act
    clamp = None
    if cfg.variant == "mmas":
        tau_max = q / (rho * best_len)
        tau_min = tau_max / (2.0 * n_eff)
        tau = jnp.clip(tau, tau_min, tau_max)
        clamp = (tau_min, tau_max)
    elif cfg.variant == "acs":
        # Parallel-ACS local rule: decay edges crossed this iteration.
        f, t = pheromone.tour_edges(res.tours, n_act)
        tau0 = q / (n_eff * jnp.maximum(best_len, 1e-9))
        ew = None
        if n_act is not None:
            # phantom-tail crossings must not decay (multiplicity 0)
            idx = jnp.arange(n, dtype=jnp.int32)
            ew = jnp.broadcast_to((idx < n_act).astype(tau.dtype),
                                  res.tours.shape).ravel()
        tau = pheromone.local_update_acs(tau, f.ravel(), t.ravel(), cfg.xi,
                                         tau0, w=ew)

    # Quantise-on-store (quant.py): the fp32 result of this step's update
    # becomes the next resident payload; metrics below read the exact fp32
    # tau this step computed, before the store rounds it.
    tau_store = tau
    if quantised:
        tau_store = quant.requantise(
            tau, state.tau, cfg.tau_dtype,
            quant.round_key(cfg.tau_round, k_q))

    new_state = ColonyState(tau_store, best_tour, best_len,
                            state.iteration + 1, key)
    if not cfg.metrics:
        return new_state, it_best_len
    from repro.obs import metrics as obs_metrics
    mets = obs_metrics.step_metrics(
        res.lengths, it_best_len, best_len, improved, tau, clamp,
        pre_ls_lengths)
    return new_state, it_best_len, mets


def run(instance: tsp.TSPInstance, cfg: ACOConfig,
        state: Optional[ColonyState] = None,
        checkpoint_cb=None, checkpoint_every: int = 0):
    """Python-loop driver (checkpointable); inner step is jitted.

    ``cfg.sparse=True`` routes to the O(n·k) paged representation
    (repro.sparse.run_sparse; returns a SparseColonyState — same
    best_tour/best_len/iteration/key fields, paged tau instead of (n, n)).
    """
    if cfg.sparse:
        from repro import sparse as sparse_mod
        return sparse_mod.run_sparse(instance, cfg, state)
    problem = make_problem(instance, cfg.nn_k)
    if state is None:
        state = init_colony(instance, cfg)
    start = int(state.iteration)
    for i in range(start, cfg.iterations):
        state = colony_step(problem, state, cfg)[0]
        if checkpoint_cb and checkpoint_every and (i + 1) % checkpoint_every == 0:
            checkpoint_cb(state)
    return state


@partial(jax.jit, static_argnames=("cfg", "iterations"))
def run_scan(problem: Problem, state: ColonyState, cfg: ACOConfig,
             iterations: int) -> tuple[ColonyState, Array]:
    """Fused multi-iteration driver (benchmarks / island inner loop).

    Returns (state, it_best per iteration); with ``cfg.metrics`` the aux
    is ``(it_best, StepMetrics)`` with every leaf stacked over iterations
    — a full convergence curve from one jitted call.  The scan carry
    threads the stagnation counter the per-step metrics cannot know.
    """
    if cfg.metrics:
        def body_m(carry, _):
            st, since = carry
            st2, it_best, m = colony_step(problem, st, cfg)
            since = jnp.where(m.improved > 0, 0, since + 1)
            return (st2, since), (it_best, m._replace(stagnation=since))

        (state, _), aux = jax.lax.scan(
            body_m, (state, jnp.asarray(0, jnp.int32)), None,
            length=iterations)
        return state, aux

    def body(st, _):
        st, it_best = colony_step(problem, st, cfg)
        return st, it_best

    return jax.lax.scan(body, state, None, length=iterations)
