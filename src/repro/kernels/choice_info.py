"""Pallas kernel: the paper's "Choice kernel" — choice = tau^alpha * eta^beta.

Memory-bound elementwise op over the (n, n) matrices; tiled (block_m,
block_n) through VMEM. Integer alpha/beta in {1,2,3,4} are specialised to
repeated multiplies (no transcendental), matching core/strategies.choice_matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_N = 512


def _ipow(x, p: float):
    if p == 1.0:
        return x
    if float(p).is_integer() and 0 < int(p) <= 4:
        y = x
        for _ in range(int(p) - 1):
            y = y * x
        return y
    return x ** p


def _choice_kernel(tau_ref, eta_ref, nact_ref, out_ref, *, alpha: float,
                   beta: float, bm: int, bn: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    out = _ipow(tau_ref[...], alpha) * _ipow(eta_ref[...], beta)
    # Phantom rows/cols (>= n_actual) of a padded instance carry eta == 0
    # already; the iota mask pins them (and tile padding) to exactly 0.
    rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, out.shape, 0)
    cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, out.shape, 1)
    n_act = nact_ref[0, 0]
    out_ref[...] = jnp.where((rows < n_act) & (cols < n_act), out, 0.0)


@functools.partial(
    jax.jit, static_argnames=("alpha", "beta", "block_m", "block_n", "interpret")
)
def choice_info(tau: jax.Array, eta: jax.Array, alpha: float = 1.0,
                beta: float = 2.0, n_actual: jax.Array | None = None,
                block_m: int = DEFAULT_BLOCK_M,
                block_n: int = DEFAULT_BLOCK_N, interpret: bool = True) -> jax.Array:
    """``n_actual``: optional traced () scalar; choice values touching a
    phantom row/column (>= n_actual) are exactly 0 — same as the pure-JAX
    route, where phantom eta == 0 zeroes the product (DESIGN.md §10)."""
    n0, n1 = tau.shape
    bm = min(block_m, n0)
    bn = min(block_n, n1)
    pad_m = (-n0) % bm
    pad_n = (-n1) % bn
    if pad_m or pad_n:
        tau = jnp.pad(tau, ((0, pad_m), (0, pad_n)))
        eta = jnp.pad(eta, ((0, pad_m), (0, pad_n)))
    n_act = jnp.asarray(max(n0, n1) if n_actual is None else n_actual,
                        jnp.int32).reshape(1, 1)
    gm, gn = tau.shape[0] // bm, tau.shape[1] // bn
    out = pl.pallas_call(
        functools.partial(_choice_kernel, alpha=alpha, beta=beta,
                          bm=bm, bn=bn),
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(tau.shape, tau.dtype),
        interpret=interpret,
    )(tau, eta, n_act)
    return out[:n0, :n1]
