"""Pallas kernel: fused pheromone evaporation + deposit (paper §IV.B).

TPU-native adaptation of the paper's scatter-to-gather (DESIGN.md §2): the
deposit matrix for an output tile (I, J) is

    D[I, J] = sum_e  [frm_e in I] * w_e * [to_e in J]
            = F_chunk^T @ (w * T_chunk)        -- an MXU matmul

with F/T one-hot slabs built *inside* the kernel from the int32 edge
endpoint vectors via iota-compares (never materialised in HBM). The edge
stream is the innermost grid axis; the output block doubles as the
accumulator, initialised with the evaporated pheromone (1-rho)*tau so
evaporation is fused for free.

Grid: (n/bi, n/bj, E/be). Edge padding uses endpoint -1 (matches no city).
Symmetric deposit is handled by the wrapper duplicating reversed edges.

Masking contract (padded instances, DESIGN.md §10): the kernel itself is
mask-complete through its edge stream — a phantom-tail edge arrives with
weight exactly 0 (contributing an exact 0 to the accumulator) and padded
edge slots arrive as -1 endpoints (matching no row/column).  The
``ops.pheromone_update`` wrapper builds that stream with
``core.pheromone.tour_edges``/``edge_weights`` (closing edge wraps at
position n_actual-1), so the kernel and pure-JAX deposits share one edge
semantics and cannot drift.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_I = 128
DEFAULT_BLOCK_J = 128
DEFAULT_BLOCK_E = 512


def _update_kernel(tau_ref, frm_ref, to_ref, w_ref, out_ref, *,
                   rho: float, bi: int, bj: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    e = pl.program_id(2)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = (1.0 - rho) * tau_ref[...]

    frm = frm_ref[...]                       # (be,)
    to = to_ref[...]
    w = w_ref[...]
    rows = i * bi + jax.lax.broadcasted_iota(jnp.int32, (1, bi), 1)
    cols = j * bj + jax.lax.broadcasted_iota(jnp.int32, (1, bj), 1)
    F = (frm[:, None] == rows).astype(jnp.float32)             # (be, bi)
    T = (to[:, None] == cols).astype(jnp.float32) * w[:, None]  # (be, bj)
    out_ref[...] += jax.lax.dot_general(
        F, T, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                     # (bi, bj)


@functools.partial(
    jax.jit,
    static_argnames=("rho", "block_i", "block_j", "block_e", "interpret"),
)
def pheromone_update(tau: jax.Array, frm: jax.Array, to: jax.Array,
                     w: jax.Array, rho: float,
                     block_i: int = DEFAULT_BLOCK_I,
                     block_j: int = DEFAULT_BLOCK_J,
                     block_e: int = DEFAULT_BLOCK_E,
                     interpret: bool = True) -> jax.Array:
    """tau (n0, n1) f32; frm/to (E,) int32 directed edges; w (E,) f32 deposit.

    Returns (1-rho)*tau + D. Pass each undirected edge twice (both
    directions) for the symmetric-TSP update. tau may be rectangular —
    the column-sharded island colony passes a (n, n/shards) shard with
    `to` indices already shifted into the local column frame.
    """
    n0, n1 = tau.shape
    bi = min(block_i, n0)
    bj = min(block_j, n1)
    be = min(block_e, max(int(frm.shape[0]), 1))
    pad_n_i = (-n0) % bi
    pad_n_j = (-n1) % bj
    pad_e = (-int(frm.shape[0])) % be
    tau_p = jnp.pad(tau, ((0, pad_n_i), (0, pad_n_j)))
    if pad_e:
        frm = jnp.pad(frm, (0, pad_e), constant_values=-1)
        to = jnp.pad(to, (0, pad_e), constant_values=-1)
        w = jnp.pad(w, (0, pad_e))
    gi = tau_p.shape[0] // bi
    gj = tau_p.shape[1] // bj
    ge = frm.shape[0] // be
    out = pl.pallas_call(
        functools.partial(_update_kernel, rho=rho, bi=bi, bj=bj),
        grid=(gi, gj, ge),
        in_specs=[
            pl.BlockSpec((bi, bj), lambda i, j, e: (i, j)),
            pl.BlockSpec((be,), lambda i, j, e: (e,)),
            pl.BlockSpec((be,), lambda i, j, e: (e,)),
            pl.BlockSpec((be,), lambda i, j, e: (e,)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, e: (i, j)),
        out_shape=jax.ShapeDtypeStruct(tau_p.shape, jnp.float32),
        interpret=interpret,
    )(tau_p, frm.astype(jnp.int32), to.astype(jnp.int32),
      w.astype(jnp.float32))
    return out[:n0, :n1]
