"""Jitted public wrappers around the Pallas kernels.

``INTERPRET`` is True on CPU (kernel bodies execute in Python for
validation) and flips to False on a real TPU backend automatically.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import choice_info as _ci
from . import pheromone_update as _pu
from . import tour_select as _ts
from . import two_opt as _to


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


INTERPRET = _interpret_default()


def choice_info(tau: jax.Array, eta: jax.Array, alpha: float = 1.0,
                beta: float = 2.0) -> jax.Array:
    return _ci.choice_info(tau, eta, alpha, beta, interpret=INTERPRET)


def tour_select(rows: jax.Array, visited: jax.Array, rand: jax.Array,
                mode: str = "iroulette") -> jax.Array:
    return _ts.tour_select(rows, visited, rand, mode, interpret=INTERPRET)


def tour_select_step(selection: str = "iroulette"):
    """StepImpl closure for core.strategies.construct_tours injection."""

    def step(key, choice_info_, st, t):
        del t
        rows = choice_info_[st.cur]
        u = jax.random.uniform(key, rows.shape, rows.dtype,
                               minval=1e-6, maxval=1.0)
        return tour_select(rows, st.visited, u, selection)

    return step


def pheromone_update(tau: jax.Array, tours: jax.Array, w: jax.Array,
                     rho: float) -> jax.Array:
    """Symmetric fused update from (m, n) tours + (m,) weights."""
    frm = tours.ravel()
    to = jnp.roll(tours, -1, axis=-1).ravel()
    ns = tours.shape[-1]
    wrep = jnp.repeat(w, ns)
    # both directions for the symmetric TSP
    f2 = jnp.concatenate([frm, to])
    t2 = jnp.concatenate([to, frm])
    w2 = jnp.concatenate([wrep, wrep])
    return _pu.pheromone_update(tau, f2, t2, w2, rho, interpret=INTERPRET)


def pheromone_update_edges(tau: jax.Array, frm: jax.Array, to: jax.Array,
                           w: jax.Array, rho: float) -> jax.Array:
    return _pu.pheromone_update(tau, frm, to, w, rho, interpret=INTERPRET)


def two_opt_best(add1: jax.Array, add2: jax.Array, rem1: jax.Array,
                 rem2: jax.Array, valid: jax.Array, thr: float = 0.0,
                 mode: str = "best") -> tuple[jax.Array, jax.Array]:
    """Per-ant best/first 2-opt move over (m, M) gathered move operands."""
    return _to.two_opt_best(add1, add2, rem1, rem2, valid, thr=float(thr),
                            mode=mode, interpret=INTERPRET)
