"""Jitted public wrappers around the Pallas kernels.

``INTERPRET`` is True on CPU (kernel bodies execute in Python for
validation) and flips to False on a real TPU backend automatically.

Every wrapper is mask-aware: ``n_actual`` (a traced () int32 scalar, the
real-city count of a padded instance — DESIGN.md §8) threads through to the
kernels, where padded tiles and phantom cities contribute exactly-zero
weight / deposit / -inf score.  The one kernel route that remains
genuinely unsupported — per-instance ``aco.Hyper`` operands, whose traced
alpha/beta exponents cannot be static kernel parameters — raises
``UnsupportedKernelRoute`` from ``check_kernel_route`` (the single typed
rejection point; DESIGN.md §10 has the support matrix).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import choice_info as _ci
from . import fused_select as _fs
from . import pheromone_update as _pu
from . import sparse_select as _ss
from . import tour_select as _ts
from . import two_opt as _to


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


INTERPRET = _interpret_default()


class UnsupportedKernelRoute(NotImplementedError):
    """A config/problem combination the kernels genuinely cannot serve."""


def check_kernel_route(masked: bool = False, hyper: bool = False,
                       sparse: bool = False,
                       selection: Optional[str] = None,
                       local_search: Optional[str] = None,
                       construction: Optional[str] = None,
                       streaming: bool = False,
                       mesh: bool = False,
                       tau_dtype: str = "fp32") -> None:
    """Validate that the kernel/sparse route supports this problem shape.

    The single typed rejection point (DESIGN.md §10/§12 support matrix):
    every route combination the kernels or the sparse representation
    genuinely cannot serve raises ``UnsupportedKernelRoute`` with one
    actionable line here, up front, instead of failing deep in a trace.

    - masked (padded) instances: fully supported everywhere (dense kernels
      and the sparse route, except sparse Partial-ACO — window positions
      index the real tour, so padded instances must run unpadded);
    - per-instance ``Hyper`` operands: unsupported on the Pallas route
      (kernel exponents are static) *and* on the sparse route (sparse
      programs specialise on static alpha/beta for the same reason);
    - sparse x roulette: inverse-CDF sampling needs a full choice row's
      cumsum — candidate pages cannot express it;
    - sparse x local search: 2-opt/Or-opt evaluate arbitrary (i, j) edges
      against the dense distance matrix;
    - sparse x streaming / mesh sharding: not wired yet (the batched
      sparse engine route is; see DESIGN.md §12 route matrix);
    - quantised tau (``tau_dtype`` bf16/int8, DESIGN.md §15): supported on
      the dense pure-JAX, Pallas, sparse, streaming, sharded and
      checkpoint routes — but *not* with per-instance ``Hyper`` operands
      (quality-gap guarantees are audited per static config; mixing
      per-slot tuning profiles over a lossy store is unvalidated).
    """
    if tau_dtype not in ("fp32", "bf16", "int8"):
        raise UnsupportedKernelRoute(
            f"unknown tau_dtype {tau_dtype!r}: the quantised pheromone "
            "store supports 'fp32' | 'bf16' | 'int8' (core/quant.py).")
    if hyper and tau_dtype != "fp32":
        raise UnsupportedKernelRoute(
            f"per-instance Hyper operands cannot run over a quantised "
            f"pheromone store (tau_dtype={tau_dtype!r}): the quantised "
            "quality gates are validated per static config only. Drop "
            "Problem.hyper or run tau_dtype='fp32'.")
    if hyper:
        if sparse:
            raise UnsupportedKernelRoute(
                "the sparse route cannot serve per-instance Hyper "
                "operands: sparse programs specialise on static "
                "alpha/beta. Drop the Hyper profiles or run the dense "
                "pure-JAX route (sparse=False, use_pallas=False).")
        raise UnsupportedKernelRoute(
            "use_pallas=True cannot serve per-instance Hyper operands: "
            "kernel alpha/beta are static compile-time parameters, but "
            "Hyper carries traced per-instance exponents. Run the "
            "pure-JAX route (use_pallas=False) for per-instance "
            "hyperparameters, or drop Problem.hyper.")
    if not sparse:
        return
    if selection == "roulette":
        raise UnsupportedKernelRoute(
            "sparse construction cannot serve selection='roulette': "
            "inverse-CDF sampling needs the full choice row's cumsum, "
            "which candidate pages do not hold. Use selection="
            "'iroulette', 'gumbel' or 'greedy', or run sparse=False.")
    if local_search is not None and local_search != "none":
        raise UnsupportedKernelRoute(
            f"sparse route cannot serve local_search={local_search!r}: "
            "2-opt/Or-opt moves evaluate arbitrary city pairs against "
            "the dense (n, n) distance matrix. Set local_search='none' "
            "or run sparse=False.")
    if construction is not None and construction not in ("data_parallel",
                                                         "partial"):
        raise UnsupportedKernelRoute(
            f"sparse route has no construction={construction!r}: the "
            "candidate-page step replaces the dense strategy ladder. Use "
            "construction='data_parallel' (standard) or 'partial' "
            "(Partial-ACO mutation), or run sparse=False.")
    if construction == "partial" and masked:
        raise UnsupportedKernelRoute(
            "sparse Partial-ACO cannot run on padded (masked) instances: "
            "mutation windows index positions of the real best tour. Run "
            "the instance unpadded (solo run_sparse) or use "
            "construction='data_parallel'.")
    if streaming:
        raise UnsupportedKernelRoute(
            "sparse instances are not wired into the streaming pool yet: "
            "slot surgery assumes dense (n, n) ColonyState buffers. Use "
            "the batched sparse engine route (solver.engine."
            "solve_instances with sparse=True) or stream dense.")
    if mesh:
        raise UnsupportedKernelRoute(
            "sparse batches are not wired through mesh sharding yet: the "
            "placement layer shards dense Problem pytrees. Run sparse "
            "batches single-device (mesh=None) or shard dense.")


def choice_info(tau: jax.Array, eta: jax.Array, alpha: float = 1.0,
                beta: float = 2.0,
                n_actual: Optional[jax.Array] = None) -> jax.Array:
    return _ci.choice_info(tau, eta, alpha, beta, n_actual,
                           interpret=INTERPRET)


def tour_select(rows: jax.Array, visited: jax.Array, rand: jax.Array,
                mode: str = "iroulette",
                n_actual: Optional[jax.Array] = None) -> jax.Array:
    return _ts.tour_select(rows, visited, rand, mode, n_actual,
                           interpret=INTERPRET)


def fused_select(tau: jax.Array, eta: jax.Array, cur: jax.Array,
                 visited: jax.Array, rand: jax.Array,
                 alpha: float = 1.0, beta: float = 2.0,
                 n_actual: Optional[jax.Array] = None,
                 mode: str = "iroulette",
                 tau_scale: Optional[jax.Array] = None) -> jax.Array:
    """Fused construction step: row gather + tau^a*eta^b + mask + select,
    without materialising the (m, n) weight matrix (kernels/fused_select).
    int8/bf16 ``tau`` payloads dequantise per tile in the kernel epilogue;
    ``tau_scale`` is the int8 per-row scale (core/quant.py)."""
    return _fs.fused_select(tau, eta, cur, visited, rand, alpha, beta,
                            n_actual, mode, tau_scale=tau_scale,
                            interpret=INTERPRET)


def sparse_select(tau_rows: jax.Array, eta_rows: jax.Array,
                  cand: jax.Array, visited: jax.Array, rand: jax.Array,
                  alpha: float = 1.0, beta: float = 2.0,
                  mode: str = "iroulette",
                  tau_scale: Optional[jax.Array] = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Sparse candidate-page selection: gather visited/rand at the K
    candidate cities, weight tau^a * eta^b, mask, select — one kernel,
    no (m, n) weight tensor (kernels/sparse_select).  Returns (pos, have):
    the winning page position and whether a selectable candidate exists
    (the sparse construction step's nearest-unvisited fallback trigger).
    int8/bf16 page payloads dequantise in the kernel epilogue; ``tau_scale``
    is the int8 (m, K) broadcast scale (core/quant.py)."""
    return _ss.sparse_select(tau_rows, eta_rows, cand, visited, rand,
                             alpha, beta, mode, tau_scale=tau_scale,
                             interpret=INTERPRET)


def tour_select_step(selection: str = "iroulette"):
    """StepImpl closure for core.strategies.construct_tours injection."""

    def step(key, choice_info_, st, t):
        del t
        rows = choice_info_[st.cur]
        u = jax.random.uniform(key, rows.shape, rows.dtype,
                               minval=1e-6, maxval=1.0)
        return tour_select(rows, st.visited, u, selection)

    return step


def pheromone_update(tau: jax.Array, tours: jax.Array, w: jax.Array,
                     rho: float,
                     n_actual: Optional[jax.Array] = None) -> jax.Array:
    """Symmetric fused update from (m, n) tours + (m,) weights.

    Mask-aware: with ``n_actual`` the closing edge wraps at position
    n_actual-1 and phantom-tail edges carry weight exactly 0, so padded
    tours deposit identically to their trimmed real tours (the same edge
    semantics as core.pheromone.tour_edges/edge_weights — reused here so
    the kernel and pure-JAX routes can never drift).
    """
    from repro.core import pheromone as _ph   # lazy: kernels stay core-free
    f, t = _ph.tour_edges(tours, n_actual)
    frm = f.ravel()
    to = t.ravel()
    wrep = _ph.edge_weights(tours, w, n_actual)
    # both directions for the symmetric TSP
    f2 = jnp.concatenate([frm, to])
    t2 = jnp.concatenate([to, frm])
    w2 = jnp.concatenate([wrep, wrep])
    return _pu.pheromone_update(tau, f2, t2, w2, rho, interpret=INTERPRET)


def pheromone_update_edges(tau: jax.Array, frm: jax.Array, to: jax.Array,
                           w: jax.Array, rho: float) -> jax.Array:
    return _pu.pheromone_update(tau, frm, to, w, rho, interpret=INTERPRET)


def two_opt_best(add1: jax.Array, add2: jax.Array, rem1: jax.Array,
                 rem2: jax.Array, valid: jax.Array, thr: float = 0.0,
                 mode: str = "best") -> tuple[jax.Array, jax.Array]:
    """Per-ant best/first 2-opt move over (m, M) gathered move operands.

    Mask-awareness lives in ``valid``: core.localsearch builds it with
    phantom-touching moves already zeroed (their inf/NaN deltas never
    reach the reduction), so padded tiles contribute +inf delta only.
    """
    return _to.two_opt_best(add1, add2, rem1, rem2, valid, thr=float(thr),
                            mode=mode, interpret=INTERPRET)
