"""Pure-jnp oracles for every Pallas kernel (bit-comparable in f32)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def choice_info(tau: jax.Array, eta: jax.Array, alpha: float,
                beta: float) -> jax.Array:
    def ipow(x, p):
        if p == 1.0:
            return x
        if float(p).is_integer() and 0 < int(p) <= 4:
            y = x
            for _ in range(int(p) - 1):
                y = y * x
            return y
        return x ** p
    return ipow(tau, alpha) * ipow(eta, beta)


def tour_select(rows: jax.Array, visited: jax.Array, rand: jax.Array,
                mode: str = "iroulette") -> jax.Array:
    mask = (visited == 0).astype(rows.dtype)
    if mode == "iroulette":
        v = rows * rand * mask
    elif mode == "gumbel":
        g = -jnp.log(-jnp.log(jnp.clip(rand, 1e-12, 1.0 - 1e-7)))
        valid = (rows > 0) & (mask > 0)
        v = jnp.where(valid, jnp.log(jnp.maximum(rows, 1e-38)) + g, _NEG_INF)
    elif mode == "greedy":
        v = jnp.where(mask > 0, rows, _NEG_INF)
    else:
        raise ValueError(mode)
    return jnp.argmax(v, axis=-1).astype(jnp.int32)


def pheromone_update(tau: jax.Array, frm: jax.Array, to: jax.Array,
                     w: jax.Array, rho: float) -> jax.Array:
    n = tau.shape[0]
    valid = (frm >= 0) & (to >= 0)
    wv = jnp.where(valid, w, 0.0)
    fi = jnp.where(valid, frm, 0)
    ti = jnp.where(valid, to, 0)
    d = jnp.zeros((n, n), jnp.float32).at[fi, ti].add(wv)
    return (1.0 - rho) * tau + d
