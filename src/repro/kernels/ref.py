"""Pure-jnp oracles for every Pallas kernel (bit-comparable in f32)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def choice_info(tau: jax.Array, eta: jax.Array, alpha: float,
                beta: float) -> jax.Array:
    def ipow(x, p):
        if p == 1.0:
            return x
        if float(p).is_integer() and 0 < int(p) <= 4:
            y = x
            for _ in range(int(p) - 1):
                y = y * x
            return y
        return x ** p
    return ipow(tau, alpha) * ipow(eta, beta)


def tour_select(rows: jax.Array, visited: jax.Array, rand: jax.Array,
                mode: str = "iroulette",
                n_actual: jax.Array | None = None) -> jax.Array:
    mask = (visited == 0).astype(rows.dtype)
    if n_actual is not None:
        cols = jnp.arange(rows.shape[-1], dtype=jnp.int32)
        mask = mask * (cols < n_actual).astype(rows.dtype)
    if mode == "iroulette":
        v = rows * rand * mask
    elif mode == "gumbel":
        g = -jnp.log(-jnp.log(jnp.clip(rand, 1e-12, 1.0 - 1e-7)))
        valid = (rows > 0) & (mask > 0)
        v = jnp.where(valid, jnp.log(jnp.maximum(rows, 1e-38)) + g, _NEG_INF)
    elif mode == "greedy":
        v = jnp.where(mask > 0, rows, _NEG_INF)
    else:
        raise ValueError(mode)
    return jnp.argmax(v, axis=-1).astype(jnp.int32)


def fused_select(tau: jax.Array, eta: jax.Array, cur: jax.Array,
                 visited: jax.Array, rand: jax.Array,
                 alpha: float = 1.0, beta: float = 2.0,
                 n_actual: jax.Array | None = None,
                 mode: str = "iroulette") -> jax.Array:
    """Oracle for the fused choice->select step: gather tau/eta rows by
    ``cur``, weight tau^alpha * eta^beta, mask visited + phantom cities,
    select.  Bitwise what gathering a precomputed choice matrix gives."""
    rows = choice_info(tau, eta, alpha, beta)[cur]
    return tour_select(rows, visited, rand, mode, n_actual)


def sparse_select(tau_rows: jax.Array, eta_rows: jax.Array,
                  cand: jax.Array, visited: jax.Array, rand: jax.Array,
                  alpha: float = 1.0, beta: float = 2.0,
                  mode: str = "iroulette") -> tuple[jax.Array, jax.Array]:
    """Oracle for the sparse candidate-page selection kernel.

    tau_rows/eta_rows (m, K) candidate-page values; cand (m, K) city ids
    (< 0 = padding); visited (m, n); rand (m, n) full-width draws gathered
    at the candidate cities.  Returns (pos, have) like the kernel: the
    page position of the argmax score and whether any unvisited
    positive-weight candidate exists.
    """
    m = cand.shape[0]
    ants = jnp.arange(m)
    safe = jnp.where(cand >= 0, cand, 0)
    gv = jnp.where(cand >= 0,
                   visited[ants[:, None], safe].astype(jnp.float32), 0.0)
    gr = jnp.where(cand >= 0, rand[ants[:, None], safe], 0.0)
    w = choice_info(tau_rows, eta_rows, alpha, beta)
    mask = (gv == 0).astype(w.dtype)
    if mode == "iroulette":
        v = w * gr * mask
    elif mode == "gumbel":
        g = -jnp.log(-jnp.log(jnp.clip(gr, 1e-12, 1.0 - 1e-7)))
        valid = (w > 0) & (mask > 0)
        v = jnp.where(valid, jnp.log(jnp.maximum(w, 1e-38)) + g, _NEG_INF)
    elif mode == "greedy":
        v = jnp.where(mask > 0, w, _NEG_INF)
    else:
        raise ValueError(mode)
    pos = jnp.argmax(v, axis=-1).astype(jnp.int32)
    have = ((w * mask).sum(-1) > 0).astype(jnp.int32)
    return pos, have


def dequant_tau(q: jax.Array, scale: jax.Array | None = None) -> jax.Array:
    """Reference dequantise for quantised tau payloads (core/quant.py):
    int8 -> f32 * per-row scale, bf16 -> f32 cast, f32 passthrough.  The
    quant oracles below dequantise the *whole* operand first and delegate
    to the fp32 oracles — the kernels' tile-local dequant epilogues must
    be bitwise equal to this (per-row scales are constant along the
    gathered axis, so gather/dequant order cannot change the operands of
    any multiply)."""
    if q.dtype == jnp.int8:
        return q.astype(jnp.float32) * scale
    if q.dtype == jnp.bfloat16:
        return q.astype(jnp.float32)
    return q


def fused_select_quant(tau_q: jax.Array, tau_scale: jax.Array | None,
                       eta: jax.Array, cur: jax.Array,
                       visited: jax.Array, rand: jax.Array,
                       alpha: float = 1.0, beta: float = 2.0,
                       n_actual: jax.Array | None = None,
                       mode: str = "iroulette") -> jax.Array:
    """Oracle for the quantised fused kernel route: full dequantise, then
    the fp32 fused_select oracle."""
    return fused_select(dequant_tau(tau_q, tau_scale), eta, cur, visited,
                        rand, alpha, beta, n_actual, mode)


def sparse_select_quant(tau_rows_q: jax.Array,
                        scale_rows: jax.Array | None,
                        eta_rows: jax.Array, cand: jax.Array,
                        visited: jax.Array, rand: jax.Array,
                        alpha: float = 1.0, beta: float = 2.0,
                        mode: str = "iroulette"
                        ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the quantised sparse-page kernel route: dequantise the
    (m, K) page payload (scale_rows already broadcast to page width), then
    the fp32 sparse_select oracle."""
    return sparse_select(dequant_tau(tau_rows_q, scale_rows), eta_rows,
                         cand, visited, rand, alpha, beta, mode)


def select_move(delta: jax.Array, valid: jax.Array, thr: float = 0.0,
                mode: str = "best") -> tuple[jax.Array, jax.Array]:
    """Local-search move selection over an (m, M) move-delta tensor.

    best: (min masked delta, first argmin index), delta=+inf if all masked.
    first: (delta, index) of the first improving move, (+inf, INT32_MAX)
    when none improves by more than thr.  The single source of truth for
    the selection semantics — core/localsearch.py uses it for both the
    2-opt and Or-opt passes, and the Pallas kernel is tested against it.
    """
    ok = valid != 0
    if mode == "best":
        v = jnp.where(ok, delta, 1e30)
        idx = jnp.argmin(v, axis=-1).astype(jnp.int32)
        val = jnp.take_along_axis(v, idx[:, None], axis=1)[:, 0]
        return val, idx
    if mode == "first":
        imp = ok & (delta < -thr)
        has = imp.any(axis=-1)
        idx = jnp.argmax(imp, axis=-1).astype(jnp.int32)
        val = jnp.take_along_axis(delta, idx[:, None], axis=1)[:, 0]
        return (jnp.where(has, val, 1e30),
                jnp.where(has, idx, jnp.int32(2**31 - 1)))
    raise ValueError(mode)


def two_opt_best(add1: jax.Array, add2: jax.Array, rem1: jax.Array,
                 rem2: jax.Array, valid: jax.Array, thr: float = 0.0,
                 mode: str = "best") -> tuple[jax.Array, jax.Array]:
    """Per-ant 2-opt move selection over (m, M) gathered move operands."""
    return select_move(add1 + add2 - rem1 - rem2, valid, thr, mode)


def pheromone_update(tau: jax.Array, frm: jax.Array, to: jax.Array,
                     w: jax.Array, rho: float) -> jax.Array:
    n = tau.shape[0]
    valid = (frm >= 0) & (to >= 0)
    wv = jnp.where(valid, w, 0.0)
    fi = jnp.where(valid, frm, 0)
    ti = jnp.where(valid, to, 0)
    d = jnp.zeros((n, n), jnp.float32).at[fi, ti].add(wv)
    return (1.0 - rho) * tau + d
