"""Pallas kernel: sparse candidate-page next-city selection (DESIGN.md §12).

The sparse construction step needs, per ant, the tabu bit and the random
draw *at its K candidate cities* — a (m, K) gather from (m, n) tensors —
followed by the tau^alpha * eta^beta weighting, masking, and selection
over the K-wide page.  This kernel fuses all of it over
(ant-block x city-tile) VMEM blocks:

- **candidate gather** of visited/rand as a batched one-hot contraction:
  per tile, ``memb[b, q, t] = (cand[b, q] == col_t)`` and a dot over the
  tile axis accumulates the gathered values across the innermost grid
  axis.  Exactly one tile matches each candidate; the other tiles add an
  exact 0.0, so the accumulated gather is bitwise a jnp gather;
- **weighting/selection** on the final tile only: the same static-
  integer-exponent folding (``choice_info._ipow``) and per-mode transform
  (``tour_select._transform``) as the dense kernels, argmax over the K
  page positions, plus the ``have`` bit (any unvisited candidate with
  positive weight) that triggers the caller's nearest-unvisited fallback.

Candidate ids < 0 (padding added here for non-divisible pages) match no
column: they gather visited=0 / rand=0 and carry zero weight, so they are
never selected while any real candidate survives, and ``have`` ignores
them.  ``kernels/ref.py`` holds the bit-comparable oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .choice_info import _ipow
from .tour_select import _transform

DEFAULT_BLOCK_M = 8
DEFAULT_BLOCK_N = 512


def _sparse_kernel(*refs, mode: str, alpha: float, beta: float,
                   block_n: int, n_tiles: int, quant: str):
    # Quantised pages (core/quant.py): tau_ref holds the resident int8/bf16
    # payload; int8 adds a (bm, K) per-row scale operand (the caller
    # broadcasts the page-row scales to page width).  Dequant runs once, in
    # the final-tile epilogue, in-register.  "none" is today's fp32 body.
    if quant == "int8":
        (tau_ref, scale_ref, eta_ref, cand_ref, vis_ref, rand_ref,
         pos_ref, have_ref, av_ref, ar_ref) = refs
    else:
        (tau_ref, eta_ref, cand_ref, vis_ref, rand_ref,
         pos_ref, have_ref, av_ref, ar_ref) = refs
    j = pl.program_id(1)
    cand = cand_ref[...]                                      # (bm, K)
    cols = j * block_n + jax.lax.broadcasted_iota(
        jnp.int32, cand.shape + (block_n,), 2)                # (bm, K, bn)
    memb = (cand[:, :, None] == cols).astype(jnp.float32)
    # batched one-hot contraction: exact gather of the tile's contribution
    gv = jax.lax.dot_general(
        memb, vis_ref[...].astype(jnp.float32),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                   # (bm, K)
    gr = jax.lax.dot_general(
        memb, rand_ref[...],
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        av_ref[...] = gv
        ar_ref[...] = gr

    @pl.when(j > 0)
    def _acc():
        av_ref[...] = av_ref[...] + gv
        ar_ref[...] = ar_ref[...] + gr

    @pl.when(j == n_tiles - 1)
    def _select():
        tau_p = tau_ref[...]
        if quant == "int8":
            # exact dequant: int8 values are exactly representable in f32,
            # and the scale operand is the same f32 the oracle multiplies.
            tau_p = tau_p.astype(jnp.float32) * scale_ref[...]
        elif quant == "bf16":
            tau_p = tau_p.astype(jnp.float32)
        w = _ipow(tau_p, alpha) * _ipow(eta_ref[...], beta)
        mask = (av_ref[...] == 0).astype(w.dtype)
        v = _transform(w, mask, ar_ref[...], mode)
        pos_ref[...] = jnp.argmax(v, axis=1).astype(jnp.int32)
        have_ref[...] = ((w * mask).sum(axis=1) > 0).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "alpha", "beta", "block_m", "block_n",
                     "interpret"),
)
def sparse_select(tau_rows: jax.Array, eta_rows: jax.Array,
                  cand: jax.Array, visited: jax.Array, rand: jax.Array,
                  alpha: float = 1.0, beta: float = 2.0,
                  mode: str = "iroulette",
                  tau_scale: jax.Array | None = None,
                  block_m: int = DEFAULT_BLOCK_M,
                  block_n: int = DEFAULT_BLOCK_N,
                  interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """tau_rows/eta_rows (m, K) f32; cand (m, K) i32 candidate city ids;
    visited (m, n) bool/int8; rand (m, n) f32.

    Returns (pos (m,) i32 — page position of the selected candidate,
    have (m,) i32 — 1 iff any unvisited positive-weight candidate exists;
    pos is only meaningful where have is 1).

    Quantised pages (core/quant.py): int8/bf16 ``tau_rows`` are
    dequantised in the kernel's final-tile epilogue; ``tau_scale`` is the
    (m, K) f32 scale (page-row scales broadcast to page width — candidate
    and overflow columns carry their own store's scale), required for int8
    and ignored otherwise.
    """
    if tau_rows.dtype == jnp.int8:
        q_mode = "int8"
        assert tau_scale is not None, "int8 tau pages need their scales"
    elif tau_rows.dtype == jnp.bfloat16:
        q_mode = "bf16"
    else:
        q_mode = "none"
        tau_rows = tau_rows.astype(jnp.float32)
    m, kk = cand.shape
    n = visited.shape[1]
    bm = min(block_m, max(m, 1))
    bn = min(block_n, n)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    visited = visited.astype(jnp.int8)
    if pad_m:
        tau_rows = jnp.pad(tau_rows, ((0, pad_m), (0, 0)))
        eta_rows = jnp.pad(eta_rows, ((0, pad_m), (0, 0)))
        cand = jnp.pad(cand, ((0, pad_m), (0, 0)), constant_values=-1)
        visited = jnp.pad(visited, ((0, pad_m), (0, 0)), constant_values=1)
        rand = jnp.pad(rand, ((0, pad_m), (0, 0)))
        if q_mode == "int8":
            tau_scale = jnp.pad(tau_scale, ((0, pad_m), (0, 0)))
    if pad_n:
        visited = jnp.pad(visited, ((0, 0), (0, pad_n)), constant_values=1)
        rand = jnp.pad(rand, ((0, 0), (0, pad_n)))
    mp, np_ = visited.shape
    gm, gn = mp // bm, np_ // bn
    in_specs = [
        pl.BlockSpec((bm, kk), lambda i, j: (i, 0)),   # tau page
        pl.BlockSpec((bm, kk), lambda i, j: (i, 0)),   # eta page
        pl.BlockSpec((bm, kk), lambda i, j: (i, 0)),   # candidate ids
        pl.BlockSpec((bm, bn), lambda i, j: (i, j)),   # visited
        pl.BlockSpec((bm, bn), lambda i, j: (i, j)),   # rand
    ]
    operands = [tau_rows, eta_rows.astype(jnp.float32),
                cand.astype(jnp.int32), visited, rand.astype(jnp.float32)]
    if q_mode == "int8":
        in_specs.insert(1, pl.BlockSpec((bm, kk), lambda i, j: (i, 0)))
        operands.insert(1, tau_scale.astype(jnp.float32))
    pos, have, _, _ = pl.pallas_call(
        functools.partial(_sparse_kernel, mode=mode, alpha=float(alpha),
                          beta=float(beta), block_n=bn, n_tiles=gn,
                          quant=q_mode),
        grid=(gm, gn),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),        # pos
            pl.BlockSpec((bm,), lambda i, j: (i,)),        # have
            pl.BlockSpec((bm, kk), lambda i, j: (i, 0)),   # vis accumulator
            pl.BlockSpec((bm, kk), lambda i, j: (i, 0)),   # rand accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp,), jnp.int32),
            jax.ShapeDtypeStruct((mp,), jnp.int32),
            jax.ShapeDtypeStruct((mp, kk), jnp.float32),
            jax.ShapeDtypeStruct((mp, kk), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    return pos[:m], have[:m]
