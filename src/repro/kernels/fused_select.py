"""Pallas kernel: fused choice->select construction step (DESIGN.md §10).

One construction step of the data-parallel strategy ladder is, on the
pure-JAX route, three materialised (m, n) tensors per scan step: the row
gather ``choice_info[cur]``, the tabu mask multiply, and the stochastic
transform fed to argmax.  This kernel fuses the whole step into one pass
over (ant-block x city-tile) VMEM blocks:

- **row gather** of tau/eta tiles by the per-ant current city, computed as
  a one-hot MXU matmul (``onehot(cur) @ tile``) so the gather vectorises on
  TPU (arbitrary dynamic gathers don't; the one-hot sum is exact in f32 —
  one 1.0 per row, zeros elsewhere — so it is bitwise a gather);
- **weighting** ``tau^alpha * eta^beta`` with the same static-integer-
  exponent folding as ``core/strategies.choice_matrix`` (bitwise-identical
  values to gathering a precomputed choice matrix);
- **visited/phantom masking**: the tabu bit and a ``col < n_actual``
  iota-compare against a scalar operand, so padded tiles (city padding and
  the phantom tail of bucketed instances) contribute exactly-zero weight
  (iroulette) / -inf score (gumbel, greedy);
- **selection**: the same per-tile partial argmax + running cross-tile
  (value, index) reduction as ``tour_select.py``.

The (m, n) weight matrix is never materialised in HBM: per grid step only
an (bm, bn) tile of it exists, in registers.  ``kernels/ref.py`` holds the
bit-comparable oracle; ``core/strategies._make_fused_step`` wires this into
the construction registry and ``core/aco.colony_step`` routes
``use_pallas=True`` + ``construction="data_parallel"`` here — which also
drops the per-iteration (n, n) choice-matrix precompute from that route
entirely.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .choice_info import _ipow
from .tour_select import _transform

DEFAULT_BLOCK_M = 8
DEFAULT_BLOCK_N = 512


def _fused_kernel(*refs, mode: str, alpha: float, beta: float,
                  block_n: int, n_rows: int, quant: str):
    # Quantised tau (core/quant.py): the tile arrives as the resident
    # int8/bf16 payload and is dequantised here, in-register, per tile —
    # the fp32 (n, n) matrix never exists.  ``quant`` is a static kernel
    # parameter; "none" is byte-for-byte today's fp32 body.
    if quant == "int8":
        (tau_ref, scale_ref, eta_ref, cur_ref, vis_ref, rand_ref, nact_ref,
         val_ref, idx_ref) = refs
    else:
        (tau_ref, eta_ref, cur_ref, vis_ref, rand_ref, nact_ref,
         val_ref, idx_ref) = refs
    j = pl.program_id(1)
    cur = cur_ref[...]                                        # (bm,)
    rows_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_rows), 1)
    onehot = (cur[:, None] == rows_iota).astype(jnp.float32)  # (bm, n)
    # Exact gather of the (bm, bn) tau/eta row tiles as an MXU matmul.
    tau_tile = tau_ref[...]
    if quant != "none":
        # int8 in [-127, 127] and bf16 are exactly representable in f32,
        # so the one-hot contraction below stays bitwise a gather.
        tau_tile = tau_tile.astype(jnp.float32)
    tau_rows = jax.lax.dot_general(
        onehot, tau_tile, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if quant == "int8":
        # Gather the per-row scale with the same one-hot contraction and
        # multiply after the payload gather: scale is constant along the
        # row, so (gathered q) * (gathered scale) multiplies exactly the
        # operands full dequantise-then-gather would — bitwise equal to
        # the ref.py oracle on the dequantised matrix.
        srow = jax.lax.dot_general(
            onehot, scale_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bm, 1)
        tau_rows = tau_rows * srow
    eta_rows = jax.lax.dot_general(
        onehot, eta_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    w = _ipow(tau_rows, alpha) * _ipow(eta_rows, beta)        # (bm, bn)

    cols = j * block_n + jax.lax.broadcasted_iota(
        jnp.int32, w.shape, 1)                                # (bm, bn)
    n_act = nact_ref[0, 0]
    mask = ((vis_ref[...] == 0) & (cols < n_act)).astype(w.dtype)
    v = _transform(w, mask, rand_ref[...], mode)

    tile_val = jnp.max(v, axis=1)
    local = jnp.argmax(v, axis=1).astype(jnp.int32)           # first max
    tile_idx = local + j * block_n

    @pl.when(j == 0)
    def _init():
        val_ref[...] = tile_val
        idx_ref[...] = tile_idx

    @pl.when(j > 0)
    def _update():
        cur_val = val_ref[...]
        cur_idx = idx_ref[...]
        better = tile_val > cur_val           # strict: first tile wins ties
        val_ref[...] = jnp.where(better, tile_val, cur_val)
        idx_ref[...] = jnp.where(better, tile_idx, cur_idx)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "alpha", "beta", "block_m", "block_n",
                     "interpret"),
)
def fused_select(tau: jax.Array, eta: jax.Array, cur: jax.Array,
                 visited: jax.Array, rand: jax.Array,
                 alpha: float = 1.0, beta: float = 2.0,
                 n_actual: jax.Array | None = None,
                 mode: str = "iroulette",
                 tau_scale: jax.Array | None = None,
                 block_m: int = DEFAULT_BLOCK_M,
                 block_n: int = DEFAULT_BLOCK_N,
                 interpret: bool = True) -> jax.Array:
    """tau/eta (n, n); cur (m,) i32; visited/rand (m, n).  -> (m,) i32.

    ``n_actual``: optional traced () scalar; cities >= n_actual (phantom
    tail of a padded instance) are never selected.  City padding added here
    for non-divisible tiles is masked the same way, so any block size gives
    the same selection; ant padding is sliced off.

    Quantised tau (core/quant.py): an int8 or bf16 ``tau`` routes the
    payload into the kernel untouched and dequantises per tile in the
    epilogue; ``tau_scale`` is the (n, 1) f32 per-row scale, required for
    int8 and ignored otherwise.
    """
    if tau.dtype == jnp.int8:
        q_mode = "int8"
        assert tau_scale is not None, "int8 tau needs its per-row scale"
    elif tau.dtype == jnp.bfloat16:
        q_mode = "bf16"
    else:
        q_mode = "none"
        tau = tau.astype(jnp.float32)
    m, n = visited.shape
    bm = min(block_m, max(m, 1))
    bn = min(block_n, n)
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    visited = visited.astype(jnp.int8)
    if pad_m:
        cur = jnp.pad(cur, (0, pad_m))
        visited = jnp.pad(visited, ((0, pad_m), (0, 0)), constant_values=1)
        rand = jnp.pad(rand, ((0, pad_m), (0, 0)), constant_values=1.0)
    if pad_n:
        tau = jnp.pad(tau, ((0, 0), (0, pad_n)))
        eta = jnp.pad(eta, ((0, 0), (0, pad_n)))
        visited = jnp.pad(visited, ((0, 0), (0, pad_n)), constant_values=1)
        rand = jnp.pad(rand, ((0, 0), (0, pad_n)), constant_values=1.0)
    n_act = jnp.asarray(n if n_actual is None else n_actual,
                        jnp.int32).reshape(1, 1)
    mp, np_ = visited.shape
    gm, gn = mp // bm, np_ // bn
    in_specs = [
        pl.BlockSpec((n, bn), lambda i, j: (0, j)),    # tau column tile
        pl.BlockSpec((n, bn), lambda i, j: (0, j)),    # eta column tile
        pl.BlockSpec((bm,), lambda i, j: (i,)),        # cur
        pl.BlockSpec((bm, bn), lambda i, j: (i, j)),   # visited
        pl.BlockSpec((bm, bn), lambda i, j: (i, j)),   # rand
        pl.BlockSpec((1, 1), lambda i, j: (0, 0)),     # n_actual
    ]
    operands = [tau, eta.astype(jnp.float32), cur.astype(jnp.int32),
                visited, rand.astype(jnp.float32), n_act]
    if q_mode == "int8":
        in_specs.insert(1, pl.BlockSpec((n, 1), lambda i, j: (0, 0)))
        operands.insert(1, tau_scale.astype(jnp.float32))
    val, idx = pl.pallas_call(
        functools.partial(_fused_kernel, mode=mode, alpha=float(alpha),
                          beta=float(beta), block_n=bn, n_rows=n,
                          quant=q_mode),
        grid=(gm, gn),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    del val
    return idx[:m]
