"""Pallas kernel: 2-opt move-delta evaluation + move selection (DESIGN.md §7).

One ant = one row (sublane), moves = lanes (the flattened n*k NN-restricted
move set).  Each grid step loads an (ant-block x move-tile) VMEM block of the
four gathered distance operands, forms the move delta

    delta = d(a, c) + d(a', c') - d(a, a') - d(c, c')

in registers, masks invalid (degenerate) moves, and reduces it to a per-tile
(value, index) pair; a running cross-tile reduction is carried in the output
block across the innermost grid axis — the same partial-best-then-reduce
scheme as tour_select.py, applied to the move tensor instead of the city row.

Two selection modes, matching core/localsearch.py:

- ``best``   running masked min of delta (first-argmin tie semantics).
- ``first``  running min of the flat move index among improving moves
             (delta < -thr), i.e. first-improvement; the winning delta rides
             along so the caller can gate on it.

The gathers that build the operand tensors stay in the wrapper (XLA): on TPU
arbitrary dynamic gathers don't vectorise inside a kernel, while the delta
arithmetic + reduction — the O(m * n * k) hot loop — runs tile-by-tile in
VMEM.  Bit-comparable to kernels/ref.py::two_opt_best in f32.

Masking contract (padded instances, DESIGN.md §10): phantom-touching moves
reach this kernel with valid=0 — core.localsearch._two_opt_operands zeroes
them before the reduction — so their inf/NaN deltas are replaced by +inf
(mode="best") or excluded from the improving set (mode="first") inside the
tile; tile padding added here carries valid=0 the same way.  A padded tour
therefore selects exactly the move its trimmed real tour would.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 8
DEFAULT_BLOCK_N = 512

_INF = 1e30
_IMAX = 2**31 - 1


def _delta_kernel(a1_ref, a2_ref, r1_ref, r2_ref, valid_ref,
                  val_ref, idx_ref, *, mode: str, thr: float, block_n: int):
    j = pl.program_id(1)
    delta = a1_ref[...] + a2_ref[...] - r1_ref[...] - r2_ref[...]
    ok = valid_ref[...] != 0

    if mode == "best":
        v = jnp.where(ok, delta, _INF)
        tile_val = jnp.min(v, axis=1)
        local = jnp.argmin(v, axis=1).astype(jnp.int32)
        tile_idx = local + j * block_n
    elif mode == "first":
        imp = ok & (delta < -thr)
        has = jnp.any(imp, axis=1)
        local = jnp.argmax(imp, axis=1).astype(jnp.int32)
        # delta at the local winner, via one-hot select (TPU-safe gather)
        lanes = jax.lax.broadcasted_iota(jnp.int32, delta.shape, 1)
        dsel = jnp.sum(jnp.where(lanes == local[:, None], delta, 0.0), axis=1)
        tile_val = jnp.where(has, dsel, _INF)
        tile_idx = jnp.where(has, local + j * block_n, _IMAX)
    else:
        raise ValueError(mode)

    @pl.when(j == 0)
    def _init():
        val_ref[...] = tile_val
        idx_ref[...] = tile_idx

    @pl.when(j > 0)
    def _update():
        cur_val = val_ref[...]
        cur_idx = idx_ref[...]
        if mode == "best":
            better = tile_val < cur_val       # strict: first tile wins ties
        else:
            better = tile_idx < cur_idx       # earliest improving move wins
        val_ref[...] = jnp.where(better, tile_val, cur_val)
        idx_ref[...] = jnp.where(better, tile_idx, cur_idx)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "thr", "block_m", "block_n", "interpret"),
)
def two_opt_best(add1: jax.Array, add2: jax.Array, rem1: jax.Array,
                 rem2: jax.Array, valid: jax.Array, thr: float = 0.0,
                 mode: str = "best", block_m: int = DEFAULT_BLOCK_M,
                 block_n: int = DEFAULT_BLOCK_N,
                 interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Operands (m, M) f32 (+ valid mask); returns ((m,) delta, (m,) idx).

    ``best``: (min masked delta, its first flat index); delta is +inf when
    every move is masked.  ``first``: (delta, index) of the first move with
    delta < -thr, (+inf, INT32_MAX) when none.  Move padding carries
    valid=0; ant padding is sliced off.
    """
    m, M = add1.shape
    bm = min(block_m, max(m, 1))
    bn = min(block_n, M)
    pad_m = (-m) % bm
    pad_n = (-M) % bn
    valid = valid.astype(jnp.int8)
    if pad_m or pad_n:
        pad2 = ((0, pad_m), (0, pad_n))
        add1, add2 = jnp.pad(add1, pad2), jnp.pad(add2, pad2)
        rem1, rem2 = jnp.pad(rem1, pad2), jnp.pad(rem2, pad2)
        valid = jnp.pad(valid, pad2)          # padding is invalid (0)
    mp, Mp = add1.shape
    gm, gn = mp // bm, Mp // bn
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out_spec = pl.BlockSpec((bm,), lambda i, j: (i,))
    val, idx = pl.pallas_call(
        functools.partial(_delta_kernel, mode=mode, thr=thr, block_n=bn),
        grid=(gm, gn),
        in_specs=[spec, spec, spec, spec, spec],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.int32),
        ],
        interpret=interpret,
    )(add1.astype(jnp.float32), add2.astype(jnp.float32),
      rem1.astype(jnp.float32), rem2.astype(jnp.float32), valid)
    return val[:m], idx[:m]
