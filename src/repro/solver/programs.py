"""Ahead-of-time program cache: kill first-request compile latency.

Every (bucket, batch, config, kind, ewt, hyper-mode, donation, mesh) tuple
the solver fabric touches is a distinct XLA program, and the first request
that needs one pays the full compile on the serving critical path — the
cold-start problem ROADMAP names (aphrodite pre-captures CUDA graphs at
``_BATCH_SIZES_TO_CAPTURE`` for exactly this reason).  This module closes
it on three layers (DESIGN.md §16):

1. **Persistent compilation cache** — ``enable_persistent_cache`` points
   JAX's executable cache at a directory, so compiled programs survive
   process restarts: the second cold start of the same service pays a
   cache *load*, not a compile.
2. **Warmup ladder** — ``ProgramCache.warm`` AOT-lowers-and-compiles the
   engine program for every bucket of ``batch.bucket_ladder`` before the
   service accepts traffic (optionally on a background thread), holding
   the compiled executables for direct dispatch.  ``engine.run_batch``
   routes through ``ProgramCache.call``: a warmed signature dispatches the
   AOT executable (``jit_cache_hit``), anything else falls back to the
   ordinary jit path (``jit_cache_miss``) and compiles on demand exactly
   as before.
3. **Neighbour-bucket routing** — ``route_bucket`` pads a request whose
   native bucket is *not* warmed into the nearest larger warmed bucket
   instead of blocking the stream on a compile.  Exactness contract: the
   neighbour route is bitwise identical to the native route, which holds
   only under width-invariant randomness — ``check_neighbour_route``
   gates it on ``cfg.draw_mode == "counter"`` (core/sampling.py), a
   pinned ant count ``cfg.m``, no local search (NN candidate width is
   bucket-dependent), non-candidate-list construction, and nearest
   rounding for quantised tau (stochastic rounding draws over the full
   (n_pad, n_pad) matrix).  Tested across AS/MMAS/ACS, quantised and
   sparse routes in tests/test_programs.py.
"""
from __future__ import annotations

import os
import threading
import time
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import aco

Array = jax.Array

MESH_NONE = "-"


def mesh_label(mesh=None) -> str:
    """Stable cache-key label for a topology: "-" for single-device,
    else the mesh's axis:size pairs (per-mesh cache keys, DESIGN.md §16)."""
    if mesh is None:
        return MESH_NONE
    return ",".join(f"{k}:{v}" for k, v in mesh.shape.items())


class ProgramKey(NamedTuple):
    """Full static signature of one compiled ``engine._run_batch_impl``.

    Everything that forces a recompile is in here: the padded bucket and
    batch width (operand shapes), the frozen ``ACOConfig`` (every static
    knob: strategy/variant/selection/draw_mode, tau_dtype/round/
    compensation, sparse geometry, metrics, ...), the loop statics, the
    donation mode, dense/sparse kind + TSPLIB rounding rule, whether the
    problem carries per-instance Hyper operands, and the mesh topology.
    """
    n_pad: int
    batch: int
    cfg: aco.ACOConfig
    max_iters: int
    patience: int
    donate: bool
    kind: str          # "dense" | "sparse"
    ewt: str
    hyper: bool
    mesh: str          # mesh_label()


# ------------------------------------------------- persistent XLA cache

def enable_persistent_cache(cache_dir: str) -> str:
    """Point JAX's persistent compilation cache at ``cache_dir``.

    Thresholds are zeroed so *every* executable is cached (the default
    min-compile-time gate would skip the small-bucket programs that
    dominate high-QPS traffic).  Process-global; call before the first
    compile.  Executables are keyed by HLO + compile options + jax/XLA
    version, so a stale directory is never wrong, only useless.
    """
    cache_dir = os.path.abspath(cache_dir)
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    return cache_dir


def persistent_cache_stats(cache_dir: str) -> dict:
    """Entry count + byte total of a persistent cache directory."""
    files = 0
    size = 0
    if os.path.isdir(cache_dir):
        for name in os.listdir(cache_dir):
            p = os.path.join(cache_dir, name)
            if os.path.isfile(p):
                files += 1
                size += os.path.getsize(p)
    return {"dir": cache_dir, "files": files, "bytes": size}


# --------------------------------------------- neighbour-route support

def check_neighbour_route(cfg: aco.ACOConfig) -> None:
    """Raise ``UnsupportedKernelRoute`` unless neighbour-bucket routing is
    bitwise-exact for this config (the route checker idiom, DESIGN.md §10).

    The padding invariants (phantom cities at inf distance, masked
    lengths/deposits) make the *deterministic* numerics width-invariant;
    the conditions here close the *stochastic* side.
    """
    from repro.kernels.ops import UnsupportedKernelRoute

    def reject(reason: str) -> None:
        raise UnsupportedKernelRoute(
            f"neighbour-bucket routing needs bucket-width-invariant "
            f"numerics: {reason}")

    if cfg.m is None:
        reject("cfg.m is None, so the ant count follows the padded bucket "
               "width (m = n_pad); pin cfg.m")
    if cfg.draw_mode != "counter":
        reject(f"draw_mode {cfg.draw_mode!r} derives per-(ant, city) "
               "randomness from flat array counters; use "
               "draw_mode='counter'")
    if cfg.local_search != "none":
        reject(f"local search {cfg.local_search!r} scans NN candidate "
               "lists of width min(nn_k, n_pad - 1), which varies per "
               "bucket")
    if cfg.sparse:
        if cfg.construction == "partial":
            reject("Partial-ACO windows are unpadded-only (masked "
                   "instances are rejected upstream)")
    elif cfg.construction in ("nn_list", "nn_list_eager"):
        reject("nn_list construction selects over candidate lists of "
               "width min(nn_k, n_pad - 1), which varies per bucket")
    from repro.core import quant
    if quant.is_quantised(cfg.tau_dtype) and cfg.tau_round != "nearest":
        reject(f"tau_round {cfg.tau_round!r} draws rounding bits over the "
               "full (n_pad, n_pad) matrix; use tau_round='nearest'")


def neighbour_supported(cfg: aco.ACOConfig) -> bool:
    from repro.kernels.ops import UnsupportedKernelRoute
    try:
        check_neighbour_route(cfg)
        return True
    except UnsupportedKernelRoute:
        return False


# ------------------------------------------------------- program cache

class ProgramCache:
    """AOT-compiled engine programs keyed by their full static signature.

    One cache serves one service (drain or streaming): ``warm`` fills it
    over a bucket ladder, ``call`` is the hot path ``engine.run_batch``
    routes through, ``route_bucket`` is the admission-time neighbour
    lookup.  Thread-safe: the warmup may run on a background thread while
    the service admits traffic (misses fall back to the jit path, so a
    half-warmed ladder is never wrong, only slower).

    ``iters_cap``: warmed programs are compiled with this ``max_iters``
    loop bound; ``effective_max_iters`` canonicalises a drain job's
    max(budgets) up to the cap so jobs of different budget mixes share one
    program.  Sound because the while_loop exits on the per-instance done
    masks — a larger static bound never changes the trajectory.
    """

    def __init__(self, telemetry=None, iters_cap: Optional[int] = None):
        from repro import obs
        self.tel = telemetry if telemetry is not None else obs.Telemetry()
        self.iters_cap = iters_cap
        self._lock = threading.Lock()
        self._programs: dict[ProgramKey, object] = {}
        self._warmed_buckets: dict[tuple[str, str], set[int]] = {}
        self._missed_keys: list[tuple] = []     # first-sight ring, bounded
        self._warm_thread: Optional[threading.Thread] = None
        self._warm_errors: list[str] = []
        self._c_hit = self.tel.registry.counter("jit_cache_hit")
        self._c_miss = self.tel.registry.counter("jit_cache_miss")
        self._c_warm_s = self.tel.registry.counter("warmup_compile_s")
        self._c_warm_programs = self.tel.registry.counter("warmup_programs")

    # ---------------------------------------------------------- key/sig
    @staticmethod
    def signature(problem, states, budgets, cfg: aco.ACOConfig,
                  max_iters: int, patience: int, donate: bool,
                  kind: str, ewt: str, mesh: str = MESH_NONE) -> ProgramKey:
        """ProgramKey of one ``run_batch`` call, read off its operands."""
        return ProgramKey(
            n_pad=int(states.best_tour.shape[-1]),
            batch=int(budgets.shape[0]),
            cfg=cfg, max_iters=int(max_iters), patience=int(patience),
            donate=bool(donate), kind=kind, ewt=ewt,
            hyper=getattr(problem, "hyper", None) is not None,
            mesh=mesh)

    def effective_max_iters(self, want: int) -> int:
        """Canonical loop bound: the warm-time cap whenever it covers the
        requested budget (one shared program), the exact budget otherwise
        (a miss, but correct)."""
        if self.iters_cap is not None and want <= self.iters_cap:
            return self.iters_cap
        return want

    # ----------------------------------------------------------- warmup
    def _templates(self, bucket: int, batch: int, cfg: aco.ACOConfig,
                   kind: str, hyper: bool):
        """Concrete template operands with exactly the production pytree
        structure — built through the same factories the services use
        (batch.make_batch / engine.init_states), so the AOT-lowered
        signature cannot drift from the live one."""
        from repro.core import tsp
        from . import batch as batch_mod
        from . import engine
        insts = [tsp.circle_instance(bucket, seed=0)] * batch
        seeds = list(range(batch))
        if kind == "sparse":
            b = batch_mod.make_sparse_batch(insts, cfg.sparse_k, bucket)
            states = engine.init_sparse_states(insts, cfg, seeds, bucket)
            ewt = b.ewt
        else:
            hypers = [aco.Hyper.make(cfg)] * batch if hyper else None
            b = batch_mod.make_batch(insts, bucket, cfg.nn_k, hypers=hypers)
            states = engine.init_states(insts, cfg, seeds, bucket, hypers)
            ewt = "EUC_2D"
        budgets = jnp.zeros((batch,), jnp.int32)
        since = jnp.zeros((batch,), jnp.int32)
        mets = None
        if cfg.metrics:
            from repro.obs import metrics as obs_metrics
            mets = obs_metrics.zeros_batch(batch)
        return b.problem, states, budgets, since, mets, ewt

    def warm_one(self, bucket: int, batch: int, cfg: aco.ACOConfig,
                 max_iters: int, patience: int, donate: bool,
                 kind: str = "dense", hyper: bool = False) -> float:
        """AOT-lower-and-compile one program; returns compile seconds
        (0.0 when the signature is already cached)."""
        from . import engine
        problem, states, budgets, since, mets, ewt = self._templates(
            bucket, batch, cfg, kind, hyper)
        key = self.signature(problem, states, budgets, cfg, max_iters,
                             patience, donate, kind, ewt)
        with self._lock:
            if key in self._programs:
                return 0.0
        t0 = time.perf_counter()
        compiled = engine.aot_lower(problem, states, budgets, cfg,
                                    max_iters, patience, since, mets,
                                    kind=kind, ewt=ewt,
                                    donate=donate).compile()
        dt = time.perf_counter() - t0
        with self._lock:
            self._programs[key] = compiled
            self._warmed_buckets.setdefault((kind, MESH_NONE),
                                            set()).add(bucket)
        self._c_warm_s.inc(dt)
        self._c_warm_programs.inc()
        self.tel.tracer.complete(f"compile b{bucket}x{batch}",
                                 self.tel.tracer.to_us(t0), dt * 1e6,
                                 process="programs", thread=kind,
                                 bucket=bucket, batch=batch,
                                 donate=donate)
        return dt

    def warm_mesh_one(self, bucket: int, batch: int, cfg: aco.ACOConfig,
                      max_iters: int, patience: int, mesh,
                      donate: bool = False, kind: str = "dense",
                      hyper: bool = False) -> float:
        """Warm the sharded route for one bucket by *executing* a budget-0
        batch through the placement layer (AOT direct dispatch is skipped
        on the mesh route — placement keeps its own per-mesh jit cache —
        so warming means populating that cache; with every budget at 0 the
        while_loop exits before the first step and the run costs only the
        compile)."""
        from . import engine
        problem, states, budgets, since, mets, ewt = self._templates(
            bucket, batch, cfg, kind, hyper)
        label = mesh_label(mesh)
        with self._lock:
            if bucket in self._warmed_buckets.get((kind, label), set()):
                return 0.0
        t0 = time.perf_counter()
        out = engine.run_batch(problem, states, budgets, cfg, max_iters,
                               patience, since, donate=donate, mesh=mesh,
                               kind=kind, ewt=ewt, mets=mets)
        out[0].best_len.block_until_ready()
        dt = time.perf_counter() - t0
        with self._lock:
            self._warmed_buckets.setdefault((kind, label),
                                            set()).add(bucket)
        self._c_warm_s.inc(dt)
        self._c_warm_programs.inc()
        self.tel.tracer.complete(f"compile b{bucket}x{batch}@{label}",
                                 self.tel.tracer.to_us(t0), dt * 1e6,
                                 process="programs", thread=kind,
                                 bucket=bucket, batch=batch, mesh=label)
        return dt

    def warm(self, buckets: Sequence[int], batch: int, cfg: aco.ACOConfig,
             max_iters: int, patience: int = 0, donate: bool = False,
             kind: str = "dense", hyper: bool = False, mesh=None,
             background: bool = False):
        """Compile the whole bucket ladder; returns a summary dict, or —
        with ``background=True`` — the started thread (``wait()`` joins
        it; misses before it finishes just take the jit path)."""
        if background:
            t = threading.Thread(
                target=self._warm_ladder,
                args=(tuple(buckets), batch, cfg, max_iters, patience,
                      donate, kind, hyper, mesh),
                name="programs-warmup", daemon=True)
            with self._lock:
                self._warm_thread = t
            t.start()
            return t
        return self._warm_ladder(tuple(buckets), batch, cfg, max_iters,
                                 patience, donate, kind, hyper, mesh)

    def _warm_ladder(self, buckets, batch, cfg, max_iters, patience,
                     donate, kind, hyper, mesh):
        per_bucket = {}
        t0 = time.perf_counter()
        for b in buckets:
            try:
                if mesh is not None:
                    per_bucket[b] = self.warm_mesh_one(
                        b, batch, cfg, max_iters, patience, mesh,
                        donate=donate, kind=kind, hyper=hyper)
                else:
                    per_bucket[b] = self.warm_one(
                        b, batch, cfg, max_iters, patience, donate,
                        kind=kind, hyper=hyper)
            except Exception as e:            # noqa: BLE001 — background
                # thread must not die silently; the bucket stays cold and
                # serve-time falls back to the jit path.
                with self._lock:
                    self._warm_errors.append(f"b{b}: {type(e).__name__}: {e}")
                self.tel.events.emit("warmup_error", bucket=b,
                                     error=f"{type(e).__name__}: {e}")
        summary = {"buckets": {str(b): round(s, 4)
                               for b, s in per_bucket.items()},
                   "batch": batch, "kind": kind,
                   "mesh": mesh_label(mesh),
                   "wall_s": time.perf_counter() - t0,
                   "errors": list(self._warm_errors)}
        self.tel.events.emit("warmup", buckets=summary["buckets"],
                             batch=batch, route=kind,
                             mesh=summary["mesh"],
                             wall_s=summary["wall_s"])
        return summary

    def wait(self, timeout: Optional[float] = None) -> None:
        """Join a background warmup, if one is running."""
        with self._lock:
            t = self._warm_thread
        if t is not None:
            t.join(timeout)

    # --------------------------------------------------------- admission
    def warmed_buckets(self, kind: str = "dense",
                       mesh: str = MESH_NONE) -> tuple[int, ...]:
        with self._lock:
            return tuple(sorted(self._warmed_buckets.get((kind, mesh), ())))

    def route_bucket(self, native: int, cfg: aco.ACOConfig,
                     kind: str = "dense", mesh: str = MESH_NONE) -> int:
        """Admission-time bucket choice: the native bucket when warmed (or
        when neighbour routing is unsupported for this config), else the
        nearest larger warmed bucket, else native (compile-on-demand,
        exactly the pre-cache behaviour)."""
        warmed = self._warmed_buckets.get((kind, mesh), ())
        if native in warmed:
            return native
        if not neighbour_supported(cfg):
            return native
        bigger = [b for b in warmed if b > native]
        return min(bigger) if bigger else native

    # ---------------------------------------------------------- hot path
    def call(self, fn, problem, states, budgets, cfg, max_iters, patience,
             since, mets, kind: str, ewt: str, donate: bool):
        """Dispatch one ``run_batch`` call: AOT executable on a warmed
        signature (``jit_cache_hit``), the ordinary jit path otherwise
        (``jit_cache_miss`` — jax compiles and caches on first sight, so
        a missed signature costs one compile, exactly as before)."""
        key = self.signature(problem, states, budgets, cfg, max_iters,
                             patience, donate, kind, ewt)
        with self._lock:
            compiled = self._programs.get(key)
        if compiled is not None:
            try:
                out = compiled(problem, states, budgets, since, mets)
                self._c_hit.inc()
                return out
            except Exception as e:            # noqa: BLE001 — an AOT
                # dispatch mismatch (layout/sharding drift) must degrade
                # to the jit path, not fail the request.
                self.tel.events.emit(
                    "aot_dispatch_fallback", bucket=key.n_pad,
                    batch=key.batch, error=f"{type(e).__name__}: {e}")
        self._c_miss.inc()
        self._note_miss(key)
        return fn(problem, states, budgets, cfg, max_iters, patience,
                  since, mets, kind=kind, ewt=ewt)

    def note_mesh_call(self, key: ProgramKey) -> None:
        """Hit/miss accounting for the sharded route (dispatch itself
        stays with the placement layer's own per-mesh cache)."""
        warmed = self._warmed_buckets.get((key.kind, key.mesh), ())
        if key.n_pad in warmed:
            self._c_hit.inc()
        else:
            self._c_miss.inc()
            self._note_miss(key)

    def _note_miss(self, key: ProgramKey) -> None:
        sig = (key.n_pad, key.batch, key.kind, key.ewt, key.mesh,
               key.max_iters, key.donate)
        with self._lock:
            if sig not in self._missed_keys and len(self._missed_keys) < 32:
                self._missed_keys.append(sig)

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._lock:
            buckets = {f"{kind}@{mesh}": sorted(bs)
                       for (kind, mesh), bs in self._warmed_buckets.items()}
            missed = [
                {"bucket": s[0], "batch": s[1], "kind": s[2], "ewt": s[3],
                 "mesh": s[4], "max_iters": s[5], "donate": s[6]}
                for s in self._missed_keys]
            n_programs = len(self._programs)
            errors = list(self._warm_errors)
        return {
            "programs": n_programs,
            "warmed_buckets": buckets,
            "hits": self._c_hit.value,
            "misses": self._c_miss.value,
            "warmup_compile_s": self._c_warm_s.value,
            "warmup_programs": self._c_warm_programs.value,
            "missed_signatures": missed,
            "warm_errors": errors,
        }
