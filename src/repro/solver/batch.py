"""Pad/bucket/stack: turn heterogeneous TSP instances into one ProblemBatch.

Bucketing policy (DESIGN.md §8): instances are padded to the next
power-of-two city count >= ``min_bucket`` so the engine compiles one program
per (bucket, batch-size, config) triple instead of one per instance size —
at most log2(n_max) buckets ever exist, and the padding waste is bounded by
2x cities (4x choice-matrix area) in the worst case.

Masking invariants for a padded instance with ``n_actual`` real cities in an
``n_pad`` bucket:

- phantom cities (indices >= n_actual) sit at **inf distance** from
  everything, so eta = 1/d is **exactly 0** and no selection rule can prefer
  them while a real city remains unvisited;
- every constructed tour is the real-city permutation at positions
  [0, n_actual) followed by the phantom tail n_actual..n_pad-1 in fixed
  index order (strategies._construct emits it deterministically);
- tour lengths, pheromone deposits and local-search moves are computed with
  the closing edge at position n_actual-1 -> position 0 and phantom
  positions masked (never multiplied against inf — always ``where``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aco, tsp
from repro.sparse import store as sparse_store


def bucket_size(n: int, min_bucket: int = 16) -> int:
    """Next power-of-two >= max(n, min_bucket)."""
    if n < 1:
        raise ValueError(f"instance size {n} < 1")
    b = min_bucket
    while b < n:
        b <<= 1
    return b


def bucket_ladder(min_n: int, max_n: int, min_bucket: int = 16
                  ) -> list[int]:
    """Every bucket size instances in [min_n, max_n] can land in.

    The single source of truth for bucket enumeration (DESIGN.md §16):
    the AOT warmup pass (solver/programs.py) compiles exactly this ladder,
    and the streaming/drain services admit into it — so "ladder warmed"
    means "no serve-time compile for any in-range instance".
    """
    if max_n < min_n:
        raise ValueError(f"max_n {max_n} < min_n {min_n}")
    lo = bucket_size(min_n, min_bucket)
    hi = bucket_size(max_n, min_bucket)
    out = [lo]
    while out[-1] < hi:
        out.append(out[-1] * 2)
    return out


def padded_problem(instance: tsp.TSPInstance, n_pad: int,
                   nn_k: int = 30,
                   hyper: Optional[aco.Hyper] = None) -> aco.Problem:
    """Mask-aware Problem for one instance padded to ``n_pad`` cities.

    ``hyper`` attaches per-instance alpha/beta/rho/q operands (DESIGN.md
    §9); batch peers must then all carry one (the stacked Problem's pytree
    structure is per-program, not per-slot).
    """
    padded = tsp.pad_instance(instance, n_pad)
    dist = jnp.asarray(padded.distances())
    eta = tsp.heuristic_matrix(dist)     # 1/inf == 0 at phantom entries
    nn = tsp.nn_lists(dist, min(nn_k, n_pad - 1))
    return aco.Problem(dist, eta, nn,
                       n_actual=jnp.asarray(instance.n, jnp.int32),
                       hyper=hyper)


@dataclasses.dataclass(frozen=True)
class ProblemBatch:
    """B instances padded to one bucket, stacked for the vmapped engine."""
    problem: aco.Problem              # leaves (B, ...); n_actual (B,)
    instances: tuple[tsp.TSPInstance, ...]
    n_pad: int

    @property
    def size(self) -> int:
        return len(self.instances)


def make_batch(instances, n_pad: int | None = None, nn_k: int = 30,
               min_bucket: int = 16,
               hypers: Optional[Sequence[Optional[aco.Hyper]]] = None
               ) -> ProblemBatch:
    """Pad every instance to a common bucket and stack into one Problem.

    ``n_pad`` defaults to the bucket covering the largest instance.
    ``hypers``: optional per-instance Hyper profiles; entries left None
    default to the batch's uniform-structure requirement via
    ``aco.Hyper.make`` at the caller (all-or-nothing — mixing Hyper and
    non-Hyper slots would change the pytree structure per slot).
    """
    instances = tuple(instances)
    if not instances:
        raise ValueError("empty batch")
    if n_pad is None:
        n_pad = bucket_size(max(i.n for i in instances), min_bucket)
    if hypers is None:
        hypers = [None] * len(instances)
    elif any(h is None for h in hypers) and any(h is not None for h in hypers):
        raise ValueError("hypers must be all-None or all-set within a batch")
    problems = [padded_problem(i, n_pad, nn_k, h)
                for i, h in zip(instances, hypers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *problems)
    return ProblemBatch(problem=stacked, instances=instances, n_pad=n_pad)


@dataclasses.dataclass(frozen=True)
class SparseBatch:
    """B sparse instances padded to one (n_pad, k) page bucket.

    Duck-typed against ProblemBatch where it matters (``instances`` /
    ``n_pad``), so ``engine.collect`` serves both.  ``ewt`` is the shared
    TSPLIB rounding rule — static to the compiled sparse program, so a
    bucket cannot mix rounding rules the way it can mix coordinates.
    """
    problem: sparse_store.SparseProblem   # leaves (B, ...); n_actual (B,)
    instances: tuple[tsp.TSPInstance, ...]
    n_pad: int
    k: int
    ewt: str

    @property
    def size(self) -> int:
        return len(self.instances)


def make_sparse_batch(instances, k: int, n_pad: int | None = None,
                      min_bucket: int = 16) -> SparseBatch:
    """Stack sparse problems into one (n_pad, k) bucket.

    Every slot carries ``n_actual`` (even exact-fit ones) so the stacked
    pytree structure is uniform and the vmapped step masks per slot.
    """
    instances = tuple(instances)
    if not instances:
        raise ValueError("empty batch")
    ewts = {i.edge_weight_type for i in instances}
    if len(ewts) > 1:
        raise ValueError(
            f"sparse bucket mixes edge weight types {sorted(ewts)}: the "
            "rounding rule is static per compiled sparse program")
    if n_pad is None:
        n_pad = bucket_size(max(i.n for i in instances), min_bucket)
    problems = [
        sparse_store.make_sparse_problem(i, k, n_pad)._replace(
            n_actual=jnp.asarray(i.n, jnp.int32))
        for i in instances]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *problems)
    return SparseBatch(problem=stacked, instances=instances, n_pad=n_pad,
                       k=k, ewt=ewts.pop())


def group_by_bucket(sizes, min_bucket: int = 16) -> dict[int, list[int]]:
    """index lists of ``sizes`` grouped by their bucket (scheduler helper)."""
    out: dict[int, list[int]] = {}
    for i, n in enumerate(sizes):
        out.setdefault(bucket_size(n, min_bucket), []).append(i)
    return out


def trim_tour(tour, n_actual: int) -> np.ndarray:
    """Drop the phantom tail of a padded tour -> real-city permutation."""
    return np.asarray(tour)[:n_actual]
