"""Continuous-batching streaming solver: slot-based engine, mid-run admission.

The drain-the-queue scheduler (service.py) admits work only at batch
boundaries: a straggler holds its whole batch, and newly arrived requests
wait for the full drain.  This module removes that barrier the way LM
serving engines do (continuous batching): each bucket owns a *resident*
stacked ``ColonyState`` of ``max_batch`` slots, and a step loop runs
fixed-size chunks of the vmapped ``colony_step`` (engine.run_batch).  After
every chunk, slots whose per-slot done mask fires (absolute iteration
counter >= budget, or patience) are harvested into ``SolveResult``s and
immediately refilled from the pending queue by **state surgery** — the
slot's rows of the stacked Problem/ColonyState pytrees are overwritten via
``.at[idx].set`` with a fresh padded problem and ``engine.init_state`` — so
one compiled program per (bucket, slots, cfg, chunk) serves an unbounded
request stream with no drain barrier.

Exactness contract (tests/test_streaming.py): any request solved through
the streaming pool yields *bitwise* the same best tour as a solo
``engine.run_batch`` call with the same seed.  Three properties compose to
give this:

- refill surgery is a pure functional ``.at[idx].set`` — sibling slots'
  leaves are untouched bitwise;
- ``run_batch`` freezes finished slots against their own *absolute*
  iteration counter, so chunked stepping composes exactly with one long
  call (the crash-recovery property of DESIGN.md §8, reused);
- a refilled slot starts from exactly the state a solo run starts from
  (``engine.init_state``: tau0 from the real instance, PRNGKey(seed)).

Admission control: waiting requests are ordered by (priority desc,
deadline asc, arrival); ``max_waiting`` bounds the queue (backpressure —
``submit`` raises AdmissionError so callers can shed load upstream).
DESIGN.md §9 records the slot lifecycle and invariants.

Telemetry (repro.obs, DESIGN.md §13): the service records everything into
a ``Telemetry`` bundle — counters/gauges/**bounded** histograms behind
``stats`` (occupancy and latency samples no longer grow without bound;
exact count/total fields keep the means and rates exact), the full slot
lifecycle (submit → admit → chunk-step → harvest/evict) as JSON-lines
events, chunk dispatches and slot residencies as Chrome-trace spans on
per-device/per-bucket tracks, and — with ``cfg.metrics`` — the in-jit
StepMetrics rows carried next to the resident ColonyState, surfaced per
result and in periodic snapshots.  Pass a ``telemetry=`` instance to
export; the default private bundle costs microseconds per event.
"""
from __future__ import annotations

import dataclasses
import time
import uuid
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import aco, pheromone, tsp
from repro.obs import metrics as obs_metrics

from . import batch as batch_mod
from . import engine
from . import placement
from .service import SolveResult


class AdmissionError(RuntimeError):
    """Raised by submit() when the waiting queue is at max_waiting."""


@dataclasses.dataclass
class StreamRequest:
    request_id: int
    instance: tsp.TSPInstance
    iterations: int
    seed: int
    priority: int = 0                  # higher admitted first
    # Latency budget in seconds after submission; tighter budgets admit
    # first.  Once ``expires_at`` (= submitted_at + deadline, stamped at
    # submit) passes, the request is *evicted* at the next step — from the
    # waiting queue or from its running slot — as an ``expired`` result.
    deadline: Optional[float] = None
    hyper: Optional[aco.Hyper] = None
    submitted_at: float = 0.0
    expires_at: Optional[float] = None  # absolute perf_counter seconds
    # Request-scoped observability (DESIGN.md §14): ``trace_id`` is minted
    # at submit and carried — with ``request_id`` and the optional
    # ``tenant`` label — on every lifecycle event and span the request
    # touches, so its full submit -> admit -> slot -> harvest journey is
    # reconstructable from one trace/event log.  Host-side only: neither
    # field reaches the solve (bitwise on==off, tests/test_serving.py).
    trace_id: str = ""
    tenant: Optional[str] = None
    # Admission bucket, stamped once at submit: the native power-of-two
    # bucket, or — with an attached program cache — the neighbour-routed
    # warmed bucket (DESIGN.md §16).  Stamped rather than recomputed so a
    # warmup finishing mid-queue can't re-route a request whose padded
    # problem was already prepped for another width.
    bucket: int = 0
    # Prepped at submit time (off the stepping critical path): the padded
    # Problem and fresh ColonyState the refill surgery writes into a slot.
    prob: Optional[aco.Problem] = None
    state: Optional[aco.ColonyState] = None

    def order_key(self):
        return (-self.priority,
                self.expires_at if self.expires_at is not None
                else float("inf"),
                self.request_id)

    def prep(self, bucket: int, cfg: aco.ACOConfig, nn_k: int) -> None:
        if self.prob is None:
            self.prob = batch_mod.padded_problem(
                self.instance, bucket, nn_k, self.hyper)
            self.state = engine.init_state(
                self.instance, cfg, self.seed, bucket, self.hyper)


class StreamingPool:
    """One bucket's resident slots: a stacked Problem/ColonyState of
    ``slots`` rows stepped together; empty slots hold a frozen dummy
    (budget 0 => done => the engine's where-merge discards their step).
    """

    def __init__(self, bucket: int, slots: int, cfg: aco.ACOConfig,
                 patience: int = 0, nn_k: Optional[int] = None,
                 per_instance_hyper: bool = False, device=None,
                 telemetry: Optional[obs.Telemetry] = None,
                 dev_label: str = "dev0",
                 slo: Optional[obs.SloTracker] = None,
                 programs=None):
        self.bucket = bucket
        self.slots = slots
        self.cfg = cfg
        self.patience = patience
        # AOT program cache (solver/programs.py): chunk steps dispatch a
        # warmed executable directly; None keeps the plain jit path.
        self.programs = programs
        self.nn_k = cfg.nn_k if nn_k is None else nn_k
        self.per_instance_hyper = per_instance_hyper
        # Telemetry sink (DESIGN.md §13): standalone pools get a private
        # in-memory bundle; the service shares one across its pools so
        # traces/events land on one timeline.  ``dev_label`` names this
        # pool's Chrome-trace process track.
        self.tel = telemetry if telemetry is not None else obs.Telemetry()
        self.dev_label = dev_label
        # Per-tenant SLO accounting (DESIGN.md §14): the service shares
        # one tracker across its pools; a standalone pool gets a private
        # one over its own registry.
        self.slo = slo if slo is not None else obs.SloTracker(
            self.tel.registry)
        # Per-device placement (DESIGN.md §11): committing the resident
        # pytrees to one device pins every chunk step there — the
        # topology-aware service runs one pool per mesh device and the
        # host dispatches all pools' (async) chunk steps before reading
        # any result back, so pools step concurrently.
        self.device = device
        # Dummy resident for empty slots: any small valid instance works —
        # budget 0 keeps it permanently frozen, so its trajectory is never
        # observed; it only has to be finite so the discarded vmap lanes
        # stay numerically tame.
        dummy = tsp.random_instance(2, seed=0)
        dhyper = aco.Hyper.make(cfg) if per_instance_hyper else None
        dprob = batch_mod.padded_problem(dummy, bucket, self.nn_k, dhyper)
        dstate = engine.init_state(dummy, cfg, 0, bucket, dhyper)
        stack = lambda x: jnp.broadcast_to(x[None], (slots,) + x.shape)
        self.problem: aco.Problem = jax.tree.map(stack, dprob)
        self.states: aco.ColonyState = jax.tree.map(stack, dstate)
        self.budgets = jnp.zeros((slots,), jnp.int32)
        self.since = jnp.zeros((slots,), jnp.int32)
        # In-jit metrics rows ride next to the resident state through the
        # same donate/freeze/refill machinery (None with metrics off).
        self.mets = obs_metrics.zeros_batch(slots) if cfg.metrics else None
        if device is not None:
            put = lambda t: jax.device_put(t, device)
            self.problem = put(self.problem)
            self.states = put(self.states)
            self.budgets = put(self.budgets)
            self.since = put(self.since)
            if self.mets is not None:
                self.mets = put(self.mets)
        self.requests: list[Optional[StreamRequest]] = [None] * slots
        self.filled_at: list[float] = [0.0] * slots
        self.fills = 0
        self.chunks = 0

    # ---------------------------------------------------------- occupancy
    @property
    def occupied(self) -> int:
        return sum(r is not None for r in self.requests)

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.requests) if r is None]

    # ------------------------------------------------------ refill surgery
    def fill_slots(self, assignments: Sequence[tuple[int, StreamRequest]]
                   ) -> None:
        """Overwrite each (slot, request) pair's rows of the resident
        pytrees with a fresh problem + initial state.  One batched
        ``.at[idx].set`` per leaf; sibling slots are untouched bitwise."""
        if not assignments:
            return
        now = time.perf_counter()
        probs, states, idx, buds = [], [], [], []
        for i, req in assignments:
            assert self.requests[i] is None, f"slot {i} occupied"
            req.prep(self.bucket, self.cfg, self.nn_k)
            probs.append(req.prob)
            states.append(req.state)
            idx.append(i)
            buds.append(req.iterations)
            self.requests[i] = req
            self.filled_at[i] = now
            self.fills += 1
        ix = jnp.asarray(idx, jnp.int32)
        newp = jax.tree.map(lambda *xs: jnp.stack(xs), *probs)
        news = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        self.problem = jax.tree.map(lambda P, x: P.at[ix].set(x),
                                    self.problem, newp)
        self.states = jax.tree.map(lambda S, x: S.at[ix].set(x),
                                   self.states, news)
        self.budgets = self.budgets.at[ix].set(jnp.asarray(buds, jnp.int32))
        self.since = self.since.at[ix].set(0)
        if self.mets is not None:          # fresh slot, fresh metrics row
            self.mets = jax.tree.map(lambda M: M.at[ix].set(0), self.mets)
        for i, req in assignments:        # resident copies own the data now
            req.prob = req.state = None
            wait_s = now - req.submitted_at
            self.slo.on_admit(req.tenant, wait_s)
            self.tel.events.emit(
                "admit", request_id=req.request_id,
                trace_id=req.trace_id,
                tenant=obs.SloTracker.tenant_label(req.tenant), slot=i,
                bucket=self.bucket, device=self.dev_label,
                n=req.instance.n, iterations=req.iterations,
                wait_s=wait_s)
            # Retroactive queue-wait span (submit -> admit) on the shared
            # "queue" track: together with the residency span stamped at
            # harvest, the request's whole journey is one span chain
            # findable by request_id/trace_id (DESIGN.md §14).
            self.tel.tracer.complete(
                f"queued req{req.request_id}",
                self.tel.tracer.to_us(req.submitted_at), wait_s * 1e6,
                process="queue", thread=f"b{self.bucket}",
                request_id=req.request_id, trace_id=req.trace_id,
                tenant=obs.SloTracker.tenant_label(req.tenant))

    # ------------------------------------------------------------ stepping
    def step_chunk(self, chunk: int) -> None:
        """Advance every active slot by up to ``chunk`` iterations.

        The resident stacked ColonyState, stagnation counters and metrics
        rows are *donated* to the jitted chunk step: the old buffers alias
        the new ones (in-place on TPU, copy-free), which is safe because
        the only references — ``self.states``/``self.since``/``self.mets``
        — are immediately rebound to the outputs (DESIGN.md §10).

        The dispatch is recorded as a span on this pool's device/bucket
        track (async: the span covers enqueue, not device wall time) and,
        when a jax.profiler capture is live, as a named profiler step."""
        with self.tel.tracer.span("chunk_dispatch", process=self.dev_label,
                                  thread=f"b{self.bucket}",
                                  occupied=self.occupied, chunk=chunk,
                                  request_ids=[r.request_id
                                               for r in self.requests
                                               if r is not None]), \
                self.tel.step_annotation("chunk_step", step_num=self.chunks):
            out = engine.run_batch(
                self.problem, self.states, self.budgets, self.cfg, chunk,
                self.patience, self.since, donate=True, mets=self.mets,
                programs=self.programs)
        if self.cfg.metrics:
            self.states, self.since, self.mets = out
        else:
            self.states, self.since = out
        self.chunks += 1

    def harvest(self) -> list[SolveResult]:
        """Collect every occupied slot whose done mask fired; free the slot
        (budget 0 refreezes it) so the next admit round can refill it."""
        it = np.asarray(self.states.iteration)
        done = it >= np.asarray(self.budgets)
        if self.patience > 0:
            done = done | (np.asarray(self.since) >= self.patience)
        return self._free_slots(
            [i for i, r in enumerate(self.requests)
             if r is not None and done[i]])

    def evict_expired(self, now: float) -> list[SolveResult]:
        """Evict occupied slots whose request deadline has passed: the
        freed slot returns a SolveResult flagged ``expired`` holding the
        best tour found so far (deadline-bounded anytime behaviour), and
        budget 0 refreezes the slot so the ordinary refill surgery can
        reuse it.  Sibling slots are untouched bitwise — freeing is the
        same ``.at[idx].set`` path harvest uses."""
        hits = [i for i, r in enumerate(self.requests)
                if r is not None and r.expires_at is not None
                and r.expires_at <= now]
        return self._free_slots(hits, expired=True)

    def _free_slots(self, hits: list[int],
                    expired: bool = False) -> list[SolveResult]:
        if not hits:
            return []
        now = time.perf_counter()
        it = np.asarray(self.states.iteration)
        lens = np.asarray(self.states.best_len)
        tours = np.asarray(self.states.best_tour)
        out = []
        freed = []
        for i in hits:
            req = self.requests[i]
            inst = req.instance
            opt = inst.known_optimum
            best_len = float(lens[i])
            latency_s = now - req.submitted_at
            tenant = obs.SloTracker.tenant_label(req.tenant)
            mrow = (obs_metrics.to_host(self.mets, i)
                    if self.mets is not None else None)
            out.append(SolveResult(
                request_id=req.request_id, name=inst.name, n=inst.n,
                bucket=self.bucket, best_len=best_len,
                best_tour=batch_mod.trim_tour(tours[i], inst.n),
                iterations=int(it[i]),
                gap_pct=(100.0 * (best_len / opt - 1.0) if opt else None),
                latency_s=latency_s,
                solve_s=now - self.filled_at[i], expired=expired,
                metrics=mrow, trace_id=req.trace_id, tenant=req.tenant))
            self.requests[i] = None
            freed.append(i)
            self.slo.on_outcome(
                req.tenant,
                "expired_running" if expired else "completed",
                latency_s, req.deadline)
            # slot-lifecycle record + a residency span on this slot's
            # Chrome-trace lane (fill -> free, stamped retroactively)
            kind = "evict" if expired else "harvest"
            ev = dict(request_id=req.request_id, trace_id=req.trace_id,
                      tenant=tenant, slot=i,
                      bucket=self.bucket, device=self.dev_label,
                      iterations=int(it[i]), best_len=best_len,
                      latency_s=latency_s)
            if mrow is not None:
                ev["metrics"] = mrow
            self.tel.events.emit(kind, **ev)
            self.tel.tracer.complete(
                f"req{req.request_id}" + ("!" if expired else ""),
                self.tel.tracer.to_us(self.filled_at[i]),
                (now - self.filled_at[i]) * 1e6,
                process=self.dev_label, thread=f"b{self.bucket}/s{i}",
                request_id=req.request_id, trace_id=req.trace_id,
                tenant=tenant, n=inst.n,
                iterations=int(it[i]), expired=expired)
        self.budgets = self.budgets.at[jnp.asarray(freed)].set(0)
        return out

    def latest_metrics(self) -> dict[int, dict]:
        """Host view of the occupied slots' in-jit metrics rows (one
        device read-back), keyed by request id — the live convergence
        snapshot the service's periodic stats emit.  Empty with
        ``cfg.metrics`` off."""
        if self.mets is None:
            return {}
        return {r.request_id: obs_metrics.to_host(self.mets, i)
                for i, r in enumerate(self.requests) if r is not None}


class StreamingSolverService:
    """Mid-run-admission request loop over per-bucket streaming pools.

    submit() only queues; admission happens at each step(): waiting
    requests (priority/deadline ordered) fill free slots of their bucket's
    pool, every non-empty pool advances one chunk, finished slots are
    harvested and immediately refillable.  ``max_waiting`` bounds the
    queue (AdmissionError).  ``per_instance_hyper=True`` makes every slot
    carry alpha/beta/rho/q operands so one bucket mixes tuning profiles
    (requests may pass a Hyper or override dict; others run the config
    profile).

    ``mesh`` places one resident pool per mesh device for every bucket
    (DESIGN.md §11): admissions route to the least-occupied pool, all
    pools' chunk steps are dispatched before any harvest, and every
    result stays bitwise what the single-pool service returns for the
    same request.  Requests whose ``deadline`` passes are evicted from
    the waiting queue and from running slots at the next step(), returned
    as ``expired``-flagged results and counted in stats().
    """

    def __init__(self, cfg: Optional[aco.ACOConfig] = None,
                 max_batch: int = 8, min_bucket: int = 16, chunk: int = 5,
                 patience: int = 0, max_waiting: Optional[int] = None,
                 per_instance_hyper: bool = False, mesh=None,
                 telemetry: Optional[obs.Telemetry] = None,
                 snapshot_every: float = 0.0, programs=None):
        if cfg is None:
            cfg = aco.ACOConfig()
        if cfg.use_pallas and per_instance_hyper:
            # the one genuinely unsupported kernel route (DESIGN.md §10):
            # per-slot Hyper operands need traced exponents, kernels need
            # static ones.  Fail eagerly with the kernels' own typed error.
            from repro.kernels import ops as kops
            kops.check_kernel_route(hyper=True, tau_dtype=cfg.tau_dtype)
        if per_instance_hyper and cfg.tau_dtype != "fp32":
            # quantised x per-slot Hyper is unsupported on every route;
            # fail at construction, not at the first admitted request.
            from repro.kernels import ops as kops
            kops.check_kernel_route(hyper=True, tau_dtype=cfg.tau_dtype)
        if cfg.sparse:
            # slot surgery assumes dense (n, n) ColonyState buffers
            from repro.kernels import ops as kops
            kops.check_kernel_route(sparse=True, streaming=True,
                                    selection=cfg.selection,
                                    local_search=cfg.local_search,
                                    construction=cfg.construction)
        if cfg.deposit not in pheromone.STRATEGIES:
            raise ValueError(f"unknown deposit strategy {cfg.deposit!r}; "
                             f"supported: {', '.join(pheromone.STRATEGIES)}")
        if chunk < 1:
            raise ValueError(f"chunk {chunk} < 1")
        if max_waiting is not None and max_waiting < 1:
            raise ValueError(f"max_waiting {max_waiting} < 1")
        self.cfg = cfg
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.chunk = chunk
        self.patience = patience
        self.max_waiting = max_waiting
        self.per_instance_hyper = per_instance_hyper
        # Prep (padded Problem + initial state) is eager only for the head
        # of the queue: it keeps refill surgery off the stepping critical
        # path without letting a deep backlog pin O(waiting * n_pad^2)
        # device memory — requests beyond the window are prepped when they
        # reach the head (at admit time) or, worst case, at fill.
        self.prep_ahead = 4 * max_batch
        # Topology (DESIGN.md §11): with a mesh, each bucket owns one
        # resident pool *per mesh device* (committed buffers pin its chunk
        # steps to that device); admissions go to the least-occupied pool
        # and every step dispatches all pools before harvesting any, so
        # the D async chunk programs overlap across devices.  Without a
        # mesh there is exactly one device slot (None = default device)
        # and behaviour is unchanged.
        self.mesh = mesh
        self._devices = (list(mesh.devices.flat) if mesh is not None
                         else [None])
        self._pools: dict[int, list[StreamingPool]] = {}
        self._waiting: list[StreamRequest] = []
        self._next_id = 0
        # Telemetry bundle (DESIGN.md §13): every ad-hoc stat lives in the
        # registry now — counters for lifecycle totals, **bounded**
        # histograms (exact count/total, windowed percentiles) for the
        # latency and occupancy samples that previously grew one float per
        # completion forever.  stats reads from here; pass ``telemetry=``
        # to share the bundle (and its trace/event exports) with a caller.
        self.tel = telemetry if telemetry is not None else obs.Telemetry()
        self.snapshot_every = snapshot_every
        # Serving observability plane (DESIGN.md §14): one per-tenant SLO
        # tracker shared by every pool, and a monotonic service birth
        # stamp every stats_snapshot carries as ``uptime_s``.
        self.slo = obs.SloTracker(self.tel.registry)
        # AOT program cache (solver/programs.py, DESIGN.md §16): resident
        # pools dispatch warmed chunk executables directly, and admission
        # neighbour-routes an unwarmed bucket into the nearest larger
        # warmed one when the config's numerics are bucket-width
        # invariant (programs.check_neighbour_route).  Streaming pools
        # always step full-width (slots = max_batch, loop bound = chunk,
        # donated), so one warmed program per bucket covers every chunk
        # the pool will ever dispatch.
        self.programs = programs
        self._t_started = time.perf_counter()
        self._c_submitted = self.tel.registry.counter("submitted")
        self._c_rejected = self.tel.registry.counter("rejected")
        self._c_completed = self.tel.registry.counter("completed")
        self._c_expired_running = self.tel.registry.counter("expired_running")
        self._c_expired_waiting = self.tel.registry.counter("expired_waiting")
        self._h_latency = self.tel.registry.histogram("latency_s")
        self._h_occupancy = self.tel.registry.histogram("occupancy")
        self._per_bucket_done: dict[int, int] = {}
        self._t_first_submit: Optional[float] = None
        self._t_last_harvest: Optional[float] = None
        self._t_last_snapshot: Optional[float] = None

    # -------------------------------------------------------------- queue
    def submit(self, instance: tsp.TSPInstance,
               iterations: Optional[int] = None,
               seed: Optional[int] = None, priority: int = 0,
               deadline: Optional[float] = None,
               hyper: Union[aco.Hyper, dict, None] = None,
               tenant: Optional[str] = None) -> int:
        """Queue a request; returns its id.  Raises AdmissionError when the
        waiting queue is full (backpressure) — resident slots don't count,
        only un-admitted requests.  ``deadline`` is a latency budget in
        seconds from now: it orders admission (tighter first) and, once
        exceeded, the request is evicted at the next step() as an
        ``expired`` result.  ``tenant`` is a pure observability label
        (per-tenant SLO accounting, DESIGN.md §14): it never influences
        ordering, placement or the solve itself."""
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline {deadline} <= 0")
        if self.max_waiting is not None and \
                len(self._waiting) >= self.max_waiting:
            self._c_rejected.inc()
            self.slo.on_reject(tenant)
            self.tel.events.emit("reject", waiting=len(self._waiting),
                                 max_waiting=self.max_waiting,
                                 tenant=obs.SloTracker.tenant_label(tenant))
            raise AdmissionError(
                f"waiting queue full ({len(self._waiting)} >= "
                f"{self.max_waiting})")
        its = iterations if iterations is not None else self.cfg.iterations
        if its < 1:
            raise ValueError(f"iterations {its} < 1")
        if hyper is not None and not self.per_instance_hyper:
            raise ValueError("per-request hyper requires "
                             "per_instance_hyper=True")
        if self.per_instance_hyper:
            if isinstance(hyper, dict):
                hyper = aco.Hyper.make(self.cfg, **hyper)
            elif hyper is None:
                hyper = aco.Hyper.make(self.cfg)
        rid = self._next_id
        self._next_id += 1
        now = time.perf_counter()
        if self._t_first_submit is None:
            self._t_first_submit = now
        req = StreamRequest(
            request_id=rid, instance=instance, iterations=its,
            seed=seed if seed is not None else self.cfg.seed + rid,
            priority=priority, deadline=deadline, hyper=hyper,
            submitted_at=now,
            expires_at=None if deadline is None else now + deadline,
            trace_id=uuid.uuid4().hex[:16], tenant=tenant)
        req.bucket = self._route_bucket(instance.n)
        # Prep the padded problem + initial state at enqueue time (so
        # refill surgery on the stepping critical path is only .at[ix].set)
        # — but only within the bounded look-ahead window.
        if len(self._waiting) < self.prep_ahead:
            req.prep(req.bucket, self.cfg, self.cfg.nn_k)
        self._waiting.append(req)
        self._c_submitted.inc()
        self.slo.on_submit(tenant)
        self.tel.events.emit(
            "submit", request_id=rid, trace_id=req.trace_id,
            tenant=obs.SloTracker.tenant_label(tenant), n=instance.n,
            bucket=req.bucket,
            iterations=its, priority=priority, deadline=deadline)
        return rid

    def _route_bucket(self, n: int) -> int:
        """Admission bucket for an ``n``-city instance: the native
        power-of-two bucket, possibly neighbour-routed into the nearest
        larger warmed bucket by an attached program cache (bitwise-exact
        per programs.check_neighbour_route)."""
        native = batch_mod.bucket_size(n, self.min_bucket)
        if self.programs is None:
            return native
        return self.programs.route_bucket(native, self.cfg, kind="dense")

    def warm_programs(self, min_n: int, max_n: int,
                      background: bool = False, ladder=None):
        """Precompile the chunk-step program for every bucket instances
        in [min_n, max_n] can land in (batch.bucket_ladder; ``ladder``
        overrides with an explicit bucket list) — the exact signature the
        resident pools dispatch: slots = max_batch, loop bound = chunk,
        donated buffers, metrics per cfg.metrics."""
        if self.programs is None:
            raise ValueError("no ProgramCache attached (programs=)")
        if ladder is None:
            ladder = batch_mod.bucket_ladder(min_n, max_n, self.min_bucket)
        return self.programs.warm(
            ladder, batch=self.max_batch, cfg=self.cfg,
            max_iters=self.chunk, patience=self.patience, donate=True,
            kind="dense", hyper=self.per_instance_hyper,
            background=background)

    @property
    def waiting(self) -> int:
        return len(self._waiting)

    @property
    def resident(self) -> int:
        return sum(p.occupied for p in self._all_pools())

    @property
    def busy(self) -> bool:
        return bool(self._waiting) or self.resident > 0

    # ---------------------------------------------------------- admission
    def _bucket_pools(self, bucket: int) -> list[StreamingPool]:
        if bucket not in self._pools:
            # AOT dispatch only for the default-device pool: the warmed
            # executables were compiled for the default device, and a
            # pool committed elsewhere would fall back (exception per
            # chunk) — those pools keep the plain jit path.
            self._pools[bucket] = [
                StreamingPool(bucket, self.max_batch, self.cfg,
                              self.patience,
                              per_instance_hyper=self.per_instance_hyper,
                              device=dev, telemetry=self.tel,
                              dev_label=placement.device_label(dev, j),
                              slo=self.slo,
                              programs=self.programs if j == 0 else None)
                for j, dev in enumerate(self._devices)]
        return self._pools[bucket]

    def _all_pools(self):
        for pools in self._pools.values():
            yield from pools

    def _admit(self) -> int:
        """Move waiting requests (priority desc, deadline asc, arrival)
        into free slots of their bucket's pools, each to the currently
        least-occupied pool (deterministic: ties break to the lowest
        device index).  Returns #admitted."""
        if not self._waiting:
            return 0
        self._waiting.sort(key=StreamRequest.order_key)
        fills: dict[tuple[int, int], list[tuple[int, StreamRequest]]] = {}
        free: dict[int, list[list[int]]] = {}   # bucket -> per-pool slots
        leftover: list[StreamRequest] = []
        for req in self._waiting:
            b = req.bucket
            if b not in free:
                free[b] = [p.free_slots() for p in self._bucket_pools(b)]
            # least-occupied == most free slots (all pools are same size);
            # the running pop keeps in-flight assignments counted.
            j = max(range(len(free[b])), key=lambda k: len(free[b][k]))
            if free[b][j]:
                fills.setdefault((b, j), []).append((free[b][j].pop(0), req))
            else:
                leftover.append(req)
        self._waiting = leftover
        n = 0
        for (b, j), assignments in fills.items():
            self._pools[b][j].fill_slots(assignments)
            n += len(assignments)
        # Prefetch prep for the queue head (next harvest's refills) —
        # between chunks, not inside the surgery itself.
        for req in leftover[:self.prep_ahead]:
            if req.prob is None:
                req.prep(req.bucket, self.cfg, self.cfg.nn_k)
        return n

    # ----------------------------------------------------------- eviction
    def _evict_expired(self) -> list[SolveResult]:
        """Deadline hardening (ROADMAP): drop deadline-expired requests
        from the waiting queue (never ran: empty tour, inf length) and
        from running slots (partial best so far); every eviction returns a
        SolveResult flagged ``expired`` and is counted in stats()."""
        now = time.perf_counter()
        out: list[SolveResult] = []
        if any(r.expires_at is not None and r.expires_at <= now
               for r in self._waiting):
            keep: list[StreamRequest] = []
            for req in self._waiting:
                if req.expires_at is not None and req.expires_at <= now:
                    wait_s = now - req.submitted_at
                    bucket = req.bucket
                    out.append(SolveResult(
                        request_id=req.request_id, name=req.instance.name,
                        n=req.instance.n, bucket=bucket,
                        best_len=float("inf"),
                        best_tour=np.zeros((0,), np.int32), iterations=0,
                        gap_pct=None, latency_s=wait_s,
                        solve_s=0.0, expired=True,
                        trace_id=req.trace_id, tenant=req.tenant))
                    self._c_expired_waiting.inc()
                    self.slo.on_outcome(req.tenant, "expired_waiting",
                                        wait_s, req.deadline)
                    tenant = obs.SloTracker.tenant_label(req.tenant)
                    self.tel.events.emit(
                        "evict_waiting", request_id=req.request_id,
                        trace_id=req.trace_id, tenant=tenant,
                        n=req.instance.n, wait_s=wait_s)
                    # never admitted: its whole life is one queue span
                    self.tel.tracer.complete(
                        f"queued req{req.request_id}!",
                        self.tel.tracer.to_us(req.submitted_at),
                        wait_s * 1e6, process="queue",
                        thread=f"b{bucket}",
                        request_id=req.request_id, trace_id=req.trace_id,
                        tenant=tenant, expired=True)
                else:
                    keep.append(req)
            self._waiting = keep
        for pool in self._all_pools():
            if pool.occupied:
                got = pool.evict_expired(now)
                self._c_expired_running.inc(len(got))
                out.extend(got)
        return out

    # ------------------------------------------------------------ stepping
    def step(self) -> list[SolveResult]:
        """One scheduler tick: evict expired deadlines, admit, advance
        every non-empty pool by one chunk, harvest.  Returns newly
        finished results (completion order, expired ones included).

        All pools' chunk steps are dispatched before any harvest reads a
        result back: jax dispatch is async, so with per-device pools the
        D chunk programs execute concurrently across the mesh while the
        host is still enqueueing/harvesting."""
        results: list[SolveResult] = list(self._evict_expired())
        self._admit()
        stepped: list[StreamingPool] = []
        for pool in self._all_pools():
            if pool.occupied == 0:
                continue
            self._h_occupancy.observe(pool.occupied / pool.slots)
            pool.step_chunk(self.chunk)         # async dispatch
            stepped.append(pool)
        for pool in stepped:
            results.extend(pool.harvest())      # first device read-back
        if results:
            done = [r for r in results if not r.expired]
            if done:
                self._t_last_harvest = time.perf_counter()
                self._c_completed.inc(len(done))
            for r in done:
                self._h_latency.observe(r.latency_s)
                self._per_bucket_done[r.bucket] = \
                    self._per_bucket_done.get(r.bucket, 0) + 1
        self._maybe_snapshot()
        return results

    def _maybe_snapshot(self) -> None:
        """Periodic stats_snapshot event (``snapshot_every`` seconds):
        the stats dict plus — with ``cfg.metrics`` — every resident
        request's live convergence row.  The event log mirrors it to the
        ``--events-out`` file, so a long replay leaves a time series.

        The *first* snapshot fires immediately (the old anchor-on-
        previous-emit skipped it until one full period had passed), and
        every snapshot stamps a monotonic-clock ``uptime_s`` measured
        from service construction."""
        if self.snapshot_every <= 0:
            return
        now = time.perf_counter()
        if self._t_last_snapshot is not None and \
                now - self._t_last_snapshot < self.snapshot_every:
            return
        self._t_last_snapshot = now
        ev = {"stats": self.stats, "uptime_s": now - self._t_started}
        if self.cfg.metrics:
            live = {}
            for pool in self._all_pools():
                live.update({str(k): v
                             for k, v in pool.latest_metrics().items()})
            ev["resident_metrics"] = live
        self.tel.events.emit("stats_snapshot", **ev)

    def run_until_drained(self, max_steps: Optional[int] = None
                          ) -> list[SolveResult]:
        """Step until queue and pools are empty (or max_steps)."""
        out: list[SolveResult] = []
        steps = 0
        while self.busy:
            out.extend(self.step())
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return out

    # --------------------------------------------------------------- stats
    @property
    def stats(self) -> dict:
        """Same keys as ever, now read from the telemetry registry.  Means
        and rates come from the histograms' exact running aggregates, so
        they are what the old unbounded lists reported; percentiles are
        estimated over the bounded recent-sample window (DESIGN.md §13)."""
        lat = self._h_latency
        completed = self._c_completed.value
        expired = (self._c_expired_waiting.value
                   + self._c_expired_running.value)
        wall = None
        if self._t_first_submit is not None and \
                self._t_last_harvest is not None:
            wall = self._t_last_harvest - self._t_first_submit
        programs = ({"programs": self.programs.stats()}
                    if self.programs is not None else {})
        return {
            **programs,
            "submitted": self._c_submitted.value,
            "rejected": self._c_rejected.value,
            "completed": completed,
            "expired": expired,
            "expired_waiting": self._c_expired_waiting.value,
            "expired_running": self._c_expired_running.value,
            "waiting": self.waiting,
            "resident": self.resident,
            "devices": len(self._devices),
            "pools": sum(len(ps) for ps in self._pools.values()),
            "chunks": sum(p.chunks for p in self._all_pools()),
            "fills": sum(p.fills for p in self._all_pools()),
            "slots": {str(b): sum(p.slots for p in ps)
                      for b, ps in sorted(self._pools.items())},
            "buckets": {str(b): c
                        for b, c in sorted(self._per_bucket_done.items())},
            "occupancy_mean": self._h_occupancy.mean(),
            "instances_per_s": (completed / wall
                                if wall and wall > 0 else 0.0),
            "latency_mean_s": lat.mean(),
            "latency_p50_s": lat.percentile(50),
            "latency_p95_s": lat.percentile(95),
            "latency_max_s": lat.max(),
            "uptime_s": time.perf_counter() - self._t_started,
            "tenants": self.slo.summary(),
        }

    def health(self) -> dict:
        """Liveness + occupancy view for the ``/healthz`` endpoint
        (obs.serving.MetricsServer): one row per resident pool plus
        queue depth — everything a scraper needs to decide the service
        is alive and how loaded it is."""
        return {
            "mode": "streaming",
            "uptime_s": time.perf_counter() - self._t_started,
            "waiting": self.waiting,
            "resident": self.resident,
            "devices": len(self._devices),
            "tenants": sorted(self.slo.tenants),
            "pools": [
                {"bucket": p.bucket, "device": p.dev_label,
                 "slots": p.slots, "occupied": p.occupied,
                 "chunks": p.chunks, "fills": p.fills}
                for p in self._all_pools()],
        }


# ------------------------------------------------------------ trace replay
@dataclasses.dataclass(frozen=True)
class TraceItem:
    """One arrival of a replayable request trace."""
    at: float                      # seconds from replay start
    instance: tsp.TSPInstance
    iterations: int
    seed: int
    priority: int = 0
    tenant: Optional[str] = None   # observability label (DESIGN.md §14)


def make_poisson_trace(num: int, rate: float, min_n: int, max_n: int,
                       seed: int = 0,
                       iterations: Union[int, Sequence[int]] = 20,
                       tenants: Optional[Sequence[str]] = None
                       ) -> list[TraceItem]:
    """Poisson arrivals (exponential inter-arrival at ``rate`` req/s) of
    mixed circle/random instances; ``iterations`` may be a sequence of
    budgets cycled deterministically over the arrivals (heterogeneous
    stragglers are what streaming wins on).  ``tenants`` cycles tenant
    labels over the arrivals the same way — instances, seeds and budgets
    are unchanged by the labels, so a multi-tenant replay solves exactly
    the single-tenant workload (per-tenant SLO parity tests rely on it)."""
    rng = np.random.RandomState(seed)
    t = 0.0
    out = []
    for i in range(num):
        t += float(rng.exponential(1.0 / rate))
        n = int(rng.randint(min_n, max_n + 1))
        inst = (tsp.circle_instance(n, seed=seed + i) if i % 2 == 0
                else tsp.random_instance(n, seed=seed + i))
        its = (int(iterations) if np.isscalar(iterations)
               else int(iterations[i % len(iterations)]))
        out.append(TraceItem(at=t, instance=inst, iterations=its,
                             seed=seed + i,
                             tenant=(tenants[i % len(tenants)]
                                     if tenants else None)))
    return out


def replay_trace(svc: StreamingSolverService, trace: Sequence[TraceItem]
                 ) -> list[SolveResult]:
    """Wall-clock replay: submit each item once its arrival time passes,
    stepping the engine in between (mid-run admission); sleeps only when
    the engine is idle and the next arrival is in the future.  When the
    service's waiting queue is full (``max_waiting`` backpressure), the
    item is held and retried after the next step drains the queue — a
    client that waits on backpressure rather than dropping the request, so
    the service's ``rejected`` stat is not inflated by retry spam."""
    start = time.perf_counter()
    i = 0
    results: list[SolveResult] = []
    while i < len(trace) or svc.busy:
        now = time.perf_counter() - start
        while i < len(trace) and trace[i].at <= now:
            if svc.max_waiting is not None and \
                    svc.waiting >= svc.max_waiting:
                break          # queue full: step to drain, then retry
            it = trace[i]
            svc.submit(it.instance, iterations=it.iterations,
                       seed=it.seed, priority=it.priority,
                       tenant=it.tenant)
            i += 1
        if svc.busy:
            results.extend(svc.step())
        elif i < len(trace):
            time.sleep(max(0.0, trace[i].at - (time.perf_counter() - start)))
    return results
