"""Placement layer: shard the engine's instance axis over a device mesh.

``engine.run_batch`` advances B colonies with one vmapped ``while_loop`` on
one device.  This module is the multi-device route (DESIGN.md §11): the
same loop body is wrapped in ``shard_map`` over a 1-D ``data`` mesh axis,
so one jitted call steps B instances spread across D devices.  There is
**no cross-device traffic inside the loop** — every instance's trajectory
is device-local (the per-instance freeze mask already makes trajectories
independent of batch composition), each shard's ``while_loop`` exits when
its *local* instances are done, and the only collective cost is the final
gather when the caller reads the sharded outputs.

Uneven batches: when B is not a multiple of the mesh's device count the
instance axis is padded with **phantom slots** — row 0 of the problem and
state replicated, with budget 0 — which the engine's done mask freezes
before the first step, exactly the mechanism ``batch.py`` uses for phantom
cities and the streaming pool uses for empty slots.  Padding happens
outside the jitted program and the outputs are sliced back to B rows, so
callers never observe it.

Exactness contract (tests/test_sharded.py): sharded ``run_batch`` is
*bitwise* identical per instance to the single-device call for any device
count, including B % D != 0 and donated buffers — each shard runs the same
per-slice numerics as the single-device vmapped program, and the phantom
slots never step.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import aco

from . import engine

Array = jax.Array


def data_mesh(devices: Optional[int] = None, axis: str = "data") -> Mesh:
    """1-D mesh over the host's first ``devices`` accelerators.

    Built by a function, never at import time (the dry-run isolation rule:
    importing this module must not touch jax device state).
    """
    n = devices if devices is not None else len(jax.devices())
    avail = len(jax.devices())
    if not 1 <= n <= avail:
        raise ValueError(f"requested {n} devices, have {avail}")
    return Mesh(jax.devices()[:n], (axis,))


def device_label(device, index: int) -> str:
    """Stable human-readable label for one mesh position — the Chrome
    trace *process* name of that device's streaming pools and the
    ``device`` field of request-scoped lifecycle events, so one
    request's journey through a sharded mesh can name the physical
    device it ran on (DESIGN.md §14).  ``device=None`` (the default,
    single-device route) stays the bare ``dev<i>``."""
    if device is None:
        return f"dev{index}"
    return f"dev{index}:{device.platform}{device.id}"


def pad_to_devices(problem: aco.Problem, states: aco.ColonyState,
                   budgets: Array, since: Array, multiple: int,
                   mets=None):
    """Pad the instance axis to a multiple of ``multiple`` with phantom
    slots: row 0's problem/state replicated with budget 0, which the
    engine's done mask freezes before the first step (their lanes are
    computed then discarded by the where-merge, so they only need finite
    numerics — a real instance's row is finite).  ``mets`` (metrics rows,
    DESIGN.md §13) pads the same way and is sliced back with the rest.
    Returns the padded pytrees and the original B."""
    b = budgets.shape[0]
    pad = (-b) % multiple
    if pad == 0:
        return problem, states, budgets, since, mets, b

    def rep(x):
        return jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])])

    problem = jax.tree.map(rep, problem)
    states = jax.tree.map(rep, states)
    budgets = jnp.concatenate([budgets, jnp.zeros((pad,), budgets.dtype)])
    since = jnp.concatenate([since, jnp.zeros((pad,), since.dtype)])
    if mets is not None:
        mets = jax.tree.map(rep, mets)
    return problem, states, budgets, since, mets, b


# One compiled program per (mesh, axis, cfg, max_iters, patience, donate):
# the same cache granularity as engine's jit, plus the topology.
_CACHE: dict = {}


def _sharded_fn(mesh: Mesh, axis: str, cfg: aco.ACOConfig, max_iters: int,
                patience: int, donate: bool):
    key = (mesh, axis, cfg, max_iters, patience, donate)
    fn = _CACHE.get(key)
    if fn is None:
        spec = P(axis)
        n_out = 3 if cfg.metrics else 2

        def local(problem, states, budgets, since, mets):
            # Per-shard body == the single-device program on the local
            # slice; its while_loop conds on *local* done masks only, so
            # shards finish independently (no collectives => divergent
            # trip counts across devices are fine).  The metrics rows
            # (leafless None with metrics off) shard with the instances.
            return engine._run_batch_impl(problem, states, budgets, cfg,
                                          max_iters, patience, since, mets)

        # check_rep=False: jax 0.4.37 has no replication rule for while_loop
        # inside shard_map; safe here — the body has no collectives and
        # every output is sharded, nothing is claimed replicated.
        sharded = shard_map(local, mesh=mesh,
                            in_specs=(spec, spec, spec, spec, spec),
                            out_specs=(spec,) * n_out, check_rep=False)
        fn = jax.jit(sharded, donate_argnums=(1, 3, 4) if donate else ())
        _CACHE[key] = fn
    return fn


def run_batch_sharded(problem: aco.Problem, states: aco.ColonyState,
                      budgets: Array, cfg: aco.ACOConfig, max_iters: int,
                      patience: int, since: Array, mesh: Mesh,
                      instance_spec: str = "data", donate: bool = False,
                      mets=None):
    """Mesh route of ``engine.run_batch``: pad B to a device multiple,
    shard the instance axis over ``mesh[instance_spec]``, run, slice back.

    Donation covers the (possibly padded) stacked state, stagnation
    counters and metrics rows, same contract as the single-device donated
    route.  Returns ``(states, since)``, plus the updated metrics rows
    when ``cfg.metrics`` is set."""
    if instance_spec not in mesh.shape:
        raise ValueError(f"mesh has no axis {instance_spec!r}; "
                         f"axes: {tuple(mesh.shape)}")
    d = mesh.shape[instance_spec]
    problem, states, budgets, since, mets, b = pad_to_devices(
        problem, states, budgets, since, d, mets)
    if donate:
        engine._quiet_cpu_donation_warning()
    fn = _sharded_fn(mesh, instance_spec, cfg, max_iters, patience, donate)
    out = fn(problem, states, budgets, since, mets)
    if out[0].best_len.shape[0] != b:        # slice phantom slots back off
        out = jax.tree.map(lambda x: x[:b], out)
    return out
