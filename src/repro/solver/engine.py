"""Batched multi-instance ACO engine: one jitted call advances B colonies.

``run_batch`` vmaps ``core.aco.colony_step`` over the instance axis inside a
``lax.while_loop``: every loop iteration advances all still-active colonies
by one ACO iteration; colonies whose per-instance budget is exhausted (or
which stagnated past ``patience`` iterations without improvement) are frozen
with a ``where``-merge, so their trajectory — including the RNG key — is
bitwise independent of how long the rest of the batch keeps running.  The
loop exits as soon as every instance is done, not at max(budgets), so a
batch of mixed budgets costs max(active) iterations, not B * max.

Batch-composition independence (tested in tests/test_solver.py): solving an
instance inside a batch of B yields *exactly* the same best tour and length
as solving it alone through the same engine with the same seed, because
per-slice numerics of the vmapped step match the B=1 program and the freeze
mask keys off each instance's own absolute iteration counter.
"""
from __future__ import annotations

import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aco, tsp
from repro.sparse import aco as sparse_aco

from . import batch as batch_mod

Array = jax.Array

_donation_warning_handled = False


def _quiet_cpu_donation_warning() -> None:
    """Buffer donation is a no-op on CPU (XLA:CPU can't alias); the
    one-line warning per compile would otherwise spam every chunked run.
    Installed lazily on the first donating call — not at import, which
    would lock the JAX backend early — and only on CPU: on TPU the same
    warning signals real aliasing breakage and must stay visible."""
    global _donation_warning_handled
    if _donation_warning_handled:
        return
    _donation_warning_handled = True
    if jax.default_backend() == "cpu":
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")


def init_state(instance: tsp.TSPInstance, cfg: aco.ACOConfig, seed: int,
               n_pad: int,
               hyper: Optional[aco.Hyper] = None) -> aco.ColonyState:
    """Fresh single-slot ColonyState: tau0 from the *real* instance.

    This is the per-slot reinitialisation the streaming pool's refill
    surgery writes into a harvested slot (solver/streaming.py) — identical
    to what a solo run starts from, which is what makes streaming results
    bitwise equal to solo runs.  ``hyper`` feeds the per-profile rho into
    the MMAS tau0.
    """
    tau0 = aco.initial_tau(
        instance, cfg, rho=None if hyper is None else float(hyper.rho))
    return aco.ColonyState(
        tau=aco.make_tau(jnp.full((n_pad, n_pad), tau0, jnp.float32), cfg),
        best_tour=jnp.arange(n_pad, dtype=jnp.int32),
        best_len=jnp.asarray(np.float32(np.inf)),
        iteration=jnp.asarray(0, jnp.int32),
        key=jax.random.PRNGKey(seed),
    )


def init_states(instances: Sequence[tsp.TSPInstance], cfg: aco.ACOConfig,
                seeds: Sequence[int], n_pad: int,
                hypers: Optional[Sequence[Optional[aco.Hyper]]] = None
                ) -> aco.ColonyState:
    """Stacked ColonyState for a bucket: tau0 from each *real* instance."""
    if hypers is None:
        hypers = [None] * len(instances)
    states = [init_state(inst, cfg, seed, n_pad, h)
              for inst, seed, h in zip(instances, seeds, hypers)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def init_sparse_states(instances: Sequence[tsp.TSPInstance],
                       cfg: aco.ACOConfig, seeds: Sequence[int],
                       n_pad: int) -> sparse_aco.SparseColonyState:
    """Stacked SparseColonyState for one (n_pad, k) bucket.

    Mirrors ``init_states``: tau0 per *real* instance, one slot per
    instance, leaves stacked on a leading B axis.
    """
    states = [sparse_aco.init_sparse_colony(inst, cfg, seed, n_pad)
              for inst, seed in zip(instances, seeds)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def _run_batch_impl(problem, states, budgets: Array, cfg: aco.ACOConfig,
                    max_iters: int, patience: int, since: Array,
                    mets=None, kind: str = "dense", ewt: str = "EUC_2D"):
    # In-jit telemetry (DESIGN.md §13): with cfg.metrics the loop carries
    # one obs.StepMetrics row per instance next to the ColonyState, merged
    # under the *same* freeze mask — a finished instance's metrics stop at
    # its final iteration, exactly like its state.  ``stagnation`` is
    # stamped from the loop's own ``since`` counter (the step can't know
    # it).  With metrics off, ``mets`` is None (a leafless pytree) and the
    # program is unchanged.
    metrics_on = cfg.metrics
    if kind == "sparse":
        step = jax.vmap(
            lambda p, s: sparse_aco.sparse_colony_step(p, s, cfg, ewt))
    else:
        step = jax.vmap(lambda p, s: aco.colony_step(p, s, cfg))
    if metrics_on and mets is None:
        from repro.obs import metrics as obs_metrics
        mets = obs_metrics.zeros_batch(budgets.shape[0])

    def done_mask(st: aco.ColonyState, since: Array) -> Array:
        d = st.iteration >= budgets
        if patience > 0:
            d = d | (since >= patience)
        return d

    def cond(carry):
        st, since, mets, it = carry
        return (it < max_iters) & ~jnp.all(done_mask(st, since))

    def body(carry):
        st, since, mets, it = carry
        out = step(problem, st)
        new = out[0]
        active = ~done_mask(st, since)

        def sel(nl, ol):
            a = active.reshape(active.shape + (1,) * (nl.ndim - 1))
            return jnp.where(a, nl, ol)

        merged = jax.tree.map(sel, new, st)
        improved = new.best_len < st.best_len
        since = jnp.where(active, jnp.where(improved, 0, since + 1), since)
        if metrics_on:
            m_new = out[2]._replace(stagnation=since)
            mets = jax.tree.map(sel, m_new, mets)
        return merged, since, mets, it + 1

    states, since, mets, _ = jax.lax.while_loop(
        cond, body, (states, since, mets, jnp.int32(0)))
    if metrics_on:
        return states, since, mets
    return states, since


_STATIC = ("cfg", "max_iters", "patience", "kind", "ewt")
_run_batch_jit = jax.jit(_run_batch_impl, static_argnames=_STATIC)
# Donating variant: the incoming stacked ColonyState (arg 1), stagnation
# counters (arg 6) and metrics rows (arg 7; leafless None with metrics
# off) alias the outputs, so a resident pool's chunk step updates its
# state in place instead of copying the whole (B, n, n) tau stack every
# chunk.  Donation is an XLA aliasing hint: a no-op on CPU, in-place on
# TPU — results are identical either way.  Callers of the donated route
# must not touch the passed-in states/since/mets afterwards.
_run_batch_donated = jax.jit(_run_batch_impl, static_argnames=_STATIC,
                             donate_argnums=(1, 6, 7))


def aot_lower(problem, states, budgets: Array, cfg: aco.ACOConfig,
              max_iters: int, patience: int, since: Array, mets=None,
              kind: str = "dense", ewt: str = "EUC_2D",
              donate: bool = False):
    """AOT-lower the single-device batch program for these operands.

    ``.compile()`` on the result yields an executable taking the dynamic
    args positionally — ``(problem, states, budgets, since, mets)`` — and
    bitwise identical to the jit path (same HLO pipeline, same donation);
    the warmup ladder (solver/programs.py) compiles through here so first
    requests skip the serve-time compile.
    """
    if donate:
        _quiet_cpu_donation_warning()
    fn = _run_batch_donated if donate else _run_batch_jit
    return fn.lower(problem, states, budgets, cfg, max_iters, patience,
                    since, mets, kind=kind, ewt=ewt)


def run_batch(problem, states, budgets: Array,
              cfg: aco.ACOConfig, max_iters: int, patience: int = 0,
              since: Optional[Array] = None, donate: bool = False,
              mesh=None, instance_spec: str = "data",
              kind: str = "dense", ewt: str = "EUC_2D", mets=None,
              programs=None):
    """Advance B colonies by up to ``max_iters`` more iterations each.

    budgets: (B,) int32 *absolute* per-instance iteration targets, compared
    against ColonyState.iteration — so chunked calls (the checkpointing
    service) compose exactly with one long call.
    patience: static; >0 additionally stops an instance after that many
    consecutive non-improving iterations.
    since: (B,) int32 consecutive-non-improving counters from a previous
    chunk (defaults to zero); returned updated so chunked patience runs
    compose exactly — the service checkpoints it next to the ColonyState.
    donate: donate ``states``/``since`` buffers to the call (resident-pool
    chunk stepping, solver/streaming.py).  The caller must drop its
    references to them afterwards: on TPU the memory is reused for the
    outputs (DESIGN.md §10 buffer-donation contract).
    mesh: a ``jax.sharding.Mesh`` routes the call through the placement
    layer (DESIGN.md §11): the instance axis is padded to a multiple of
    the mesh's ``instance_spec`` axis size with already-done phantom slots
    and sharded over the devices via shard_map — bitwise identical per
    instance to the single-device call, any device count, uneven B % D
    included.
    mets: with ``cfg.metrics``, (B,)-stacked obs.StepMetrics rows from a
    previous chunk (defaults to zeros) — returned updated as a third
    element ``(states, since, mets)`` so chunked metrics compose exactly;
    ignored (and the return stays ``(states, since)``) with metrics off.
    programs: an attached ``programs.ProgramCache`` dispatches a warmed
    signature's AOT executable directly (jit_cache_hit) and falls back to
    the ordinary jit path otherwise (jit_cache_miss) — bitwise identical
    either way.  On the mesh route dispatch stays with the placement
    layer's own per-mesh cache; the program cache only keeps hit/miss
    accounting.
    """
    if since is None:
        since = jnp.zeros_like(budgets)
    if cfg.metrics:
        if mets is None:
            from repro.obs import metrics as obs_metrics
            mets = obs_metrics.zeros_batch(budgets.shape[0])
    else:
        mets = None
    if mesh is not None:
        if kind == "sparse":
            from repro.kernels import ops as kops
            kops.check_kernel_route(sparse=True, mesh=True,
                                    selection=cfg.selection,
                                    local_search=cfg.local_search,
                                    construction=cfg.construction)
        from . import placement
        if programs is not None:
            from . import programs as programs_mod
            programs.note_mesh_call(programs.signature(
                problem, states, budgets, cfg, max_iters, patience,
                donate, kind, ewt, mesh=programs_mod.mesh_label(mesh)))
        return placement.run_batch_sharded(problem, states, budgets, cfg,
                                           max_iters, patience, since, mesh,
                                           instance_spec, donate, mets)
    if donate:
        _quiet_cpu_donation_warning()
    fn = _run_batch_donated if donate else _run_batch_jit
    if programs is not None:
        return programs.call(fn, problem, states, budgets, cfg, max_iters,
                             patience, since, mets, kind=kind, ewt=ewt,
                             donate=donate)
    return fn(problem, states, budgets, cfg, max_iters, patience, since,
              mets, kind=kind, ewt=ewt)


def solve_instances(instances: Sequence[tsp.TSPInstance], cfg: aco.ACOConfig,
                    iterations: Optional[Sequence[int]] = None,
                    seeds: Optional[Sequence[int]] = None,
                    n_pad: Optional[int] = None, patience: int = 0,
                    nn_k: Optional[int] = None,
                    hypers: Optional[Sequence[aco.Hyper]] = None,
                    mesh=None):
    """Convenience one-shot: batch, init, run. All instances in one bucket.

    ``hypers``: per-instance alpha/beta/rho/q profiles (aco.Hyper); one
    bucket then mixes tuning profiles in a single compiled program.
    ``mesh``: shard the instance axis over the mesh (placement layer).
    ``cfg.sparse`` routes the whole bucket through the O(n*k) paged
    representation (returns (stacked SparseColonyState, SparseBatch));
    unsupported sparse combinations raise ``UnsupportedKernelRoute``.
    """
    instances = tuple(instances)
    its = list(iterations) if iterations is not None else \
        [cfg.iterations] * len(instances)
    sds = list(seeds) if seeds is not None else \
        [cfg.seed + i for i in range(len(instances))]
    if cfg.sparse:
        if hypers is not None and any(h is not None for h in hypers):
            from repro.kernels import ops as kops
            kops.check_kernel_route(hyper=True, sparse=True)
        sb = batch_mod.make_sparse_batch(instances, cfg.sparse_k, n_pad)
        sparse_aco.check_sparse_route(cfg, masked=True)
        sstates = init_sparse_states(instances, cfg, sds, sb.n_pad)
        budgets = jnp.asarray(its, jnp.int32)
        sstates = run_batch(sb.problem, sstates, budgets, cfg,
                            int(max(its)), patience, donate=True,
                            mesh=mesh, kind="sparse", ewt=sb.ewt)[0]
        return sstates, sb
    b = batch_mod.make_batch(instances, n_pad,
                             nn_k if nn_k is not None else cfg.nn_k,
                             hypers=hypers)
    states = init_states(instances, cfg, sds, b.n_pad, hypers)
    budgets = jnp.asarray(its, jnp.int32)
    # freshly-built states are never reused: safe to donate their buffers
    states = run_batch(b.problem, states, budgets, cfg, int(max(its)),
                       patience, donate=True, mesh=mesh)[0]
    return states, b


def collect(states, b) -> list[dict]:
    """Host-side per-instance results with phantom tails trimmed.

    Duck-typed over dense ``ProblemBatch`` and sparse ``SparseBatch``:
    both carry ``instances``, both states stacks carry
    best_len/best_tour/iteration.
    """
    lens = np.asarray(states.best_len)
    its = np.asarray(states.iteration)
    tours = np.asarray(states.best_tour)
    out = []
    for i, inst in enumerate(b.instances):
        out.append({
            "name": inst.name,
            "n": inst.n,
            "best_len": float(lens[i]),
            "best_tour": batch_mod.trim_tour(tours[i], inst.n),
            "iterations": int(its[i]),
            "known_optimum": inst.known_optimum,
        })
    return out
