"""Queue-and-scheduler solver service: submit -> bucket -> batch -> collect.

The service accumulates solve requests, groups them by padded bucket size
(batch.bucket_size), slices each bucket into batches of at most
``max_batch`` instances, and runs each batch through the vmapped engine.
One compiled program per (bucket, batch-size, config) serves every request
that ever lands in that bucket.

Crash recovery: with ``checkpoint_dir`` set, each batch job runs under the
runtime Supervisor — the job advances in ``ckpt_chunk``-iteration chunks,
checkpointing the stacked ColonyState after each chunk; on any failure the
supervisor restores the newest checkpoint and resumes.  Because run_batch
freezes instances against their *absolute* iteration counter, the chunked
trajectory is identical to an uninterrupted run (tests/test_solver.py
injects a crash and asserts result equality).
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
import uuid
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import CheckpointManager
from repro.core import aco, pheromone, tsp
from repro.obs import metrics as obs_metrics
from repro.runtime.supervisor import Supervisor, SupervisorConfig

from . import batch as batch_mod
from . import engine


@dataclasses.dataclass
class SolveRequest:
    request_id: int
    instance: tsp.TSPInstance
    iterations: int
    seed: int
    submitted_at: float
    # Request-scoped observability (DESIGN.md §14): host-side correlation
    # fields only — neither reaches the solve.
    trace_id: str = ""
    tenant: Optional[str] = None


@dataclasses.dataclass
class SolveResult:
    request_id: int
    name: str
    n: int
    bucket: int
    best_len: float
    best_tour: np.ndarray          # (n,) real-city permutation (tail trimmed)
    iterations: int
    gap_pct: Optional[float]       # vs known optimum, when available
    latency_s: float               # submit -> result
    solve_s: float                 # batch wall time (shared by batch peers)
    # Deadline eviction (streaming hardening, DESIGN.md §9): True when the
    # request's deadline expired before completion — the result then holds
    # the best tour found so far (or an empty tour if it never ran).
    expired: bool = False
    # In-jit convergence metrics row (repro.obs, DESIGN.md §13) read at
    # harvest — final stagnation, tau saturation, LS acceptance, ... —
    # None unless the solve ran with ``ACOConfig.metrics=True``.
    metrics: Optional[dict] = None
    # Request-scoped correlation (DESIGN.md §14): the trace id minted at
    # submit and the caller's tenant label (None = untagged).
    trace_id: str = ""
    tenant: Optional[str] = None


class SolverService:
    """Bucket-scheduling request loop over the batched engine."""

    def __init__(self, cfg: Optional[aco.ACOConfig] = None,
                 max_batch: int = 8, min_bucket: int = 16,
                 patience: int = 0,
                 checkpoint_dir: Optional[str] = None,
                 ckpt_chunk: int = 25, mesh=None,
                 telemetry: Optional[obs.Telemetry] = None,
                 programs=None):
        if cfg is None:
            cfg = aco.ACOConfig()
        if cfg.deposit not in pheromone.STRATEGIES:
            raise ValueError(f"unknown deposit strategy {cfg.deposit!r}; "
                             f"supported: {', '.join(pheromone.STRATEGIES)}")
        if cfg.sparse:
            # fail at construction, not mid-drain: batched slots are always
            # padded (masked), and a mesh needs the dense placement layer
            from repro.kernels import ops as kops
            kops.check_kernel_route(masked=True, sparse=True,
                                    selection=cfg.selection,
                                    local_search=cfg.local_search,
                                    construction=cfg.construction,
                                    mesh=mesh is not None)
        self.cfg = cfg
        self.max_batch = max_batch
        self.min_bucket = min_bucket
        self.patience = patience
        self.checkpoint_dir = checkpoint_dir
        self.ckpt_chunk = ckpt_chunk
        # Topology (DESIGN.md §11): with a mesh, every batch job's instance
        # axis is sharded over the mesh devices by the placement layer —
        # results stay bitwise what the single-device scheduler returns.
        self.mesh = mesh
        # Telemetry bundle (repro.obs, DESIGN.md §13): service phases
        # (bucket / dispatch / collect) land as spans on one timeline, the
        # job lifecycle as JSON-lines events, and — with ``cfg.metrics`` —
        # each result carries its in-jit convergence row.  The default
        # private bundle costs microseconds; pass ``telemetry=`` to export.
        self.tel = telemetry if telemetry is not None else obs.Telemetry()
        # Serving observability plane (DESIGN.md §14): per-tenant SLO
        # accounting over labeled registry families + a service birth
        # stamp for /healthz uptime.
        self.slo = obs.SloTracker(self.tel.registry)
        # AOT program cache (solver/programs.py, DESIGN.md §16): when
        # attached, jobs whose full static signature was warmed dispatch
        # the precompiled executable; jobs are padded with budget-0
        # phantom slots to ``max_batch`` so the batch width is canonical
        # (batch-composition independence makes the padding exact), and
        # admission may neighbour-route an unwarmed bucket into the
        # nearest larger warmed one when the config's numerics are
        # bucket-width invariant.
        self.programs = programs
        self._t_started = time.perf_counter()
        self._queue: list[SolveRequest] = []
        self._next_id = 0
        self._jobs_run = 0
        self.stats: dict = {}

    # ------------------------------------------------------------- queue
    def submit(self, instance: tsp.TSPInstance,
               iterations: Optional[int] = None,
               seed: Optional[int] = None,
               tenant: Optional[str] = None) -> int:
        rid = self._next_id
        self._next_id += 1
        trace_id = uuid.uuid4().hex[:16]
        self._queue.append(SolveRequest(
            request_id=rid, instance=instance,
            iterations=iterations if iterations is not None
            else self.cfg.iterations,
            seed=seed if seed is not None else self.cfg.seed + rid,
            submitted_at=time.perf_counter(),
            trace_id=trace_id, tenant=tenant))
        self.tel.registry.counter("submitted").inc()
        self.slo.on_submit(tenant)
        self.tel.events.emit("submit", request_id=rid, trace_id=trace_id,
                             tenant=obs.SloTracker.tenant_label(tenant),
                             n=instance.n,
                             bucket=self._route_bucket(instance.n))
        return rid

    def _route_bucket(self, n: int) -> int:
        """Admission bucket for an ``n``-city instance: the native
        power-of-two bucket, possibly neighbour-routed into the nearest
        larger warmed bucket by an attached program cache (bitwise-exact
        per programs.check_neighbour_route)."""
        native = batch_mod.bucket_size(n, self.min_bucket)
        if self.programs is None:
            return native
        from . import programs as programs_mod
        return self.programs.route_bucket(
            native, self.cfg,
            kind="sparse" if self.cfg.sparse else "dense",
            mesh=programs_mod.mesh_label(self.mesh))

    def warm_programs(self, min_n: int, max_n: int,
                      background: bool = False, ladder=None):
        """Precompile the drain job program for every bucket instances in
        [min_n, max_n] can land in (batch.bucket_ladder; ``ladder``
        overrides with an explicit bucket list).  Sets the program
        cache's ``iters_cap`` (default: cfg.iterations) so jobs with
        budgets under the cap share the warmed loop bound."""
        if self.programs is None:
            raise ValueError("no ProgramCache attached (programs=)")
        if self.programs.iters_cap is None:
            self.programs.iters_cap = self.cfg.iterations
        if ladder is None:
            ladder = batch_mod.bucket_ladder(min_n, max_n, self.min_bucket)
        return self.programs.warm(
            ladder, batch=self.max_batch, cfg=self.cfg,
            max_iters=self.programs.iters_cap, patience=self.patience,
            donate=False, kind="sparse" if self.cfg.sparse else "dense",
            mesh=self.mesh, background=background)

    @property
    def pending(self) -> int:
        return len(self._queue)

    def health(self) -> dict:
        """Liveness view for the ``/healthz`` endpoint (DESIGN.md §14)."""
        return {
            "mode": "drain",
            "uptime_s": time.perf_counter() - self._t_started,
            "pending": self.pending,
            "jobs_run": self._jobs_run,
            "devices": (int(np.prod(list(self.mesh.shape.values())))
                        if self.mesh is not None else 1),
            "tenants": sorted(self.slo.tenants),
        }

    # --------------------------------------------------------- scheduler
    def run(self) -> list[SolveResult]:
        """Drain the queue: bucket, batch, solve, collect. Returns results
        in request order; throughput/latency stats land in self.stats."""
        queue, self._queue = self._queue, []
        if not queue:
            return []
        t0 = time.perf_counter()
        with self.tel.tracer.span("bucket", requests=len(queue)):
            by_bucket: dict[int, list[SolveRequest]] = {}
            for req in queue:
                b = self._route_bucket(req.instance.n)
                by_bucket.setdefault(b, []).append(req)

        results: list[SolveResult] = []
        batch_count = 0
        for bucket in sorted(by_bucket):
            reqs = by_bucket[bucket]
            for i in range(0, len(reqs), self.max_batch):
                results.extend(self._run_job(bucket, reqs[i:i + self.max_batch]))
                batch_count += 1
        wall = time.perf_counter() - t0
        lat = [r.latency_s for r in results]
        self.stats = {
            "requests": len(queue),
            "devices": (int(np.prod(list(self.mesh.shape.values())))
                        if self.mesh is not None else 1),
            "batches": batch_count,
            "buckets": {str(b): len(rs) for b, rs in sorted(by_bucket.items())},
            "wall_s": wall,
            "instances_per_s": len(queue) / max(wall, 1e-9),
            "latency_mean_s": float(np.mean(lat)),
            "latency_max_s": float(np.max(lat)),
            "uptime_s": time.perf_counter() - self._t_started,
            "tenants": self.slo.summary(),
        }
        if self.programs is not None:
            self.stats["programs"] = self.programs.stats()
        return sorted(results, key=lambda r: r.request_id)

    # --------------------------------------------------------------- job
    def _run_job(self, bucket: int,
                 reqs: list[SolveRequest]) -> list[SolveResult]:
        instances = [r.instance for r in reqs]
        seeds = [r.seed for r in reqs]
        budgets_list = [r.iterations for r in reqs]
        max_it = max(budgets_list)
        if self.programs is not None:
            # Canonicalise the job's static signature to the warmed one:
            # the loop bound rounds up to the cache's iters_cap (the
            # while_loop exits on the done masks, so a larger bound never
            # changes the trajectory), and the batch pads to max_batch
            # with budget-0 phantom slots (frozen before their first
            # step; batch-composition independence keeps the real slots
            # bitwise).  collect() below zips against ``reqs`` only, so
            # phantom rows never surface.
            max_it = self.programs.effective_max_iters(max_it)
            pad = self.max_batch - len(reqs)
            if pad > 0:
                instances = instances + [instances[0]] * pad
                seeds = seeds + [0] * pad
                budgets_list = budgets_list + [0] * pad
        job_id = self._jobs_run
        self._jobs_run += 1

        thread = f"b{bucket}"
        with self.tel.tracer.span("prep", thread=thread, n=len(reqs)):
            if self.cfg.sparse:
                b = batch_mod.make_sparse_batch(instances,
                                                self.cfg.sparse_k, bucket)
                init = lambda: engine.init_sparse_states(instances,
                                                         self.cfg, seeds,
                                                         bucket)
                kind, ewt = "sparse", b.ewt
            else:
                b = batch_mod.make_batch(instances, bucket, self.cfg.nn_k)
                init = lambda: engine.init_states(instances, self.cfg,
                                                  seeds, bucket)
                kind, ewt = "dense", "EUC_2D"
            budgets = jnp.asarray(budgets_list, jnp.int32)
        metrics_on = self.cfg.metrics

        t0 = time.perf_counter()
        for req in reqs:               # queue wait ends at job dispatch
            self.slo.on_admit(req.tenant, t0 - req.submitted_at)
        with self.tel.tracer.span("dispatch", thread=thread, job=job_id,
                                  bucket=bucket, batch=len(reqs),
                                  max_iters=max_it,
                                  request_ids=[r.request_id
                                               for r in reqs]):
            if self.checkpoint_dir:
                # checkpointed state = (ColonyState, stagnation counters,
                # [metrics rows]): everything the chunked loop carries must
                # survive chunk boundaries for patience runs — and final
                # metrics — to compose exactly with an uninterrupted one.
                chunk = self.ckpt_chunk
                mgr = CheckpointManager(
                    os.path.join(self.checkpoint_dir,
                                 f"job{job_id:04d}_b{bucket}"),
                    async_write=False)
                if metrics_on:
                    init_st = lambda: (init(), jnp.zeros_like(budgets),
                                       obs_metrics.zeros_batch(
                                           budgets.shape[0]))
                else:
                    init_st = lambda: (init(), jnp.zeros_like(budgets))
                sup = Supervisor(
                    SupervisorConfig(total_steps=math.ceil(max_it / chunk),
                                     ckpt_every=1),
                    mgr,
                    init_st,
                    lambda st, i: engine.run_batch(
                        b.problem, st[0], budgets, self.cfg, chunk,
                        self.patience, st[1], mesh=self.mesh, kind=kind,
                        ewt=ewt,
                        mets=st[2] if metrics_on else None,
                        programs=self.programs))
                out_st = sup.run()
            else:
                out_st = engine.run_batch(b.problem, init(), budgets,
                                          self.cfg, max_it, self.patience,
                                          mesh=self.mesh, kind=kind,
                                          ewt=ewt, programs=self.programs)
            states = out_st[0]
            mets = out_st[2] if metrics_on else None
            states.best_len.block_until_ready()
        solve_s = time.perf_counter() - t0

        with self.tel.tracer.span("collect", thread=thread, job=job_id):
            now = time.perf_counter()
            out = []
            for k, (req, row) in enumerate(
                    zip(reqs, engine.collect(states, b))):
                opt = row["known_optimum"]
                latency_s = now - req.submitted_at
                out.append(SolveResult(
                    request_id=req.request_id, name=row["name"],
                    n=row["n"], bucket=bucket, best_len=row["best_len"],
                    best_tour=row["best_tour"],
                    iterations=row["iterations"],
                    gap_pct=(100.0 * (row["best_len"] / opt - 1.0)
                             if opt else None),
                    latency_s=latency_s, solve_s=solve_s,
                    metrics=(obs_metrics.to_host(mets, k)
                             if mets is not None else None),
                    trace_id=req.trace_id, tenant=req.tenant))
                self.slo.on_outcome(req.tenant, "completed", latency_s,
                                    None)
                self.tel.events.emit(
                    "harvest", request_id=req.request_id,
                    trace_id=req.trace_id,
                    tenant=obs.SloTracker.tenant_label(req.tenant),
                    bucket=bucket, job_id=job_id,
                    best_len=row["best_len"],
                    iterations=row["iterations"], latency_s=latency_s)
            self.tel.registry.counter("completed").inc(len(out))
            self.tel.events.emit("job", job_id=job_id, bucket=bucket,
                                 batch=len(out), solve_s=solve_s,
                                 request_ids=[r.request_id for r in reqs])
        return out
