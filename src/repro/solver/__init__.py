"""Instance-batched solver: pad/bucket/vmap many TSP instances per device.

- batch.py    pads instances to power-of-two bucket sizes with masked
              phantom cities and stacks them into a ProblemBatch;
- engine.py   vmaps core.aco.colony_step over the instance axis so one
              jitted call advances B colonies, with per-instance budgets
              and a done-mask early exit;
- service.py  a queue-and-scheduler request loop with throughput stats
              and supervisor/checkpoint crash recovery.

See DESIGN.md §8 for the bucketing policy and masking invariants.
"""
from .batch import (ProblemBatch, bucket_size, make_batch,  # noqa: F401
                    padded_problem)
from .engine import init_states, run_batch, solve_instances  # noqa: F401
from .service import SolveResult, SolverService  # noqa: F401
