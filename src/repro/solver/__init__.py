"""Instance-batched solver: pad/bucket/vmap many TSP instances per device.

- batch.py     pads instances to power-of-two bucket sizes with masked
               phantom cities and stacks them into a ProblemBatch;
- engine.py    vmaps core.aco.colony_step over the instance axis so one
               jitted call advances B colonies, with per-instance budgets
               and a done-mask early exit;
- service.py   a drain-the-queue request loop with throughput stats
               and supervisor/checkpoint crash recovery;
- streaming.py continuous batching: per-bucket resident slot pools with
               chunked stepping, harvest + refill surgery mid-run,
               priority/deadline admission, deadline eviction and
               backpressure;
- placement.py multi-device fabric: shard_map the engine's instance axis
               over a 1-D device mesh (phantom-slot padding for uneven
               batches), place streaming pools per device.

See DESIGN.md §8 for the bucketing policy and masking invariants, §9 for
the streaming slot lifecycle, §11 for the placement layer.
"""
from .batch import (ProblemBatch, bucket_size, make_batch,  # noqa: F401
                    padded_problem)
from .engine import (init_state, init_states, run_batch,  # noqa: F401
                     solve_instances)
from .placement import data_mesh, run_batch_sharded  # noqa: F401
from .service import SolveResult, SolverService  # noqa: F401
from .streaming import (AdmissionError, StreamingPool,  # noqa: F401
                        StreamingSolverService, TraceItem,
                        make_poisson_trace, replay_trace)
