"""Instance-batched solver: pad/bucket/vmap many TSP instances per device.

- batch.py     pads instances to power-of-two bucket sizes with masked
               phantom cities and stacks them into a ProblemBatch;
- engine.py    vmaps core.aco.colony_step over the instance axis so one
               jitted call advances B colonies, with per-instance budgets
               and a done-mask early exit;
- service.py   a drain-the-queue request loop with throughput stats
               and supervisor/checkpoint crash recovery;
- streaming.py continuous batching: per-bucket resident slot pools with
               chunked stepping, harvest + refill surgery mid-run,
               priority/deadline admission, deadline eviction and
               backpressure;
- placement.py multi-device fabric: shard_map the engine's instance axis
               over a 1-D device mesh (phantom-slot padding for uneven
               batches), place streaming pools per device;
- programs.py  ahead-of-time program cache: persistent XLA compile cache,
               bucket-ladder warmup (AOT lower+compile before traffic)
               and neighbour-bucket admission routing.

See DESIGN.md §8 for the bucketing policy and masking invariants, §9 for
the streaming slot lifecycle, §11 for the placement layer, §16 for the
program cache.
"""
from .batch import (ProblemBatch, bucket_ladder, bucket_size,  # noqa: F401
                    make_batch, padded_problem)
from .engine import (init_state, init_states, run_batch,  # noqa: F401
                     solve_instances)
from .programs import (ProgramCache, ProgramKey,  # noqa: F401
                       check_neighbour_route, enable_persistent_cache,
                       persistent_cache_stats)
from .placement import data_mesh, run_batch_sharded  # noqa: F401
from .service import SolveResult, SolverService  # noqa: F401
from .streaming import (AdmissionError, StreamingPool,  # noqa: F401
                        StreamingSolverService, TraceItem,
                        make_poisson_trace, replay_trace)
