"""Fault-tolerant checkpointing for arbitrary pytrees (ACO colonies, LM
train states, data-pipeline cursors).

Design points for cluster operation:
- **Atomicity**: write to ``<dir>/.tmp.<step>`` then ``os.replace`` — a
  checkpoint either exists completely or not at all; a job killed mid-write
  never corrupts the restore point.
- **Async**: ``save`` can hand off to a background thread (double-buffered,
  one in flight) so the training loop is not blocked by disk.
- **Self-describing**: the treedef and leaf dtypes/shapes are stored in the
  npz next to the data; restore needs no template (but accepts one for
  sharded placement).
- **Elastic restore**: ``restore_to_sharding`` device_puts each leaf to a
  target NamedSharding, so a checkpoint written on one mesh restarts on
  another (resharding-on-restore). Stacked island states can be re-split
  across a different island count via ``reshard_islands``.
"""
from __future__ import annotations

import json
import os
import queue
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree: Any, step: Optional[int] = None) -> None:
    """Atomic npz save of a pytree. bf16 (and other npz-hostile dtypes) are
    stored as uint16/uint8 raw bits with the true dtype recorded in meta."""
    leaves, treedef = _flatten(tree)
    arrs = {}
    dtypes = {}
    for i, x in enumerate(leaves):
        a = np.asarray(jax.device_get(x))
        if a.dtype.kind == "V" or a.dtype.name not in np.sctypeDict:
            dtypes[str(i)] = a.dtype.name           # e.g. bfloat16
            a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
        arrs[f"leaf_{i}"] = a
    meta = {"treedef": str(treedef), "n_leaves": len(leaves), "step": step,
            "raw_dtypes": dtypes}
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, __meta__=json.dumps(meta), **arrs)
    os.replace(tmp, path)


def load_pytree(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (leaf order match)."""
    import ml_dtypes
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        raw = meta.get("raw_dtypes", {})
        leaves = []
        for i in range(meta["n_leaves"]):
            a = z[f"leaf_{i}"]
            if str(i) in raw:
                a = a.view(np.dtype(getattr(ml_dtypes, raw[str(i)])))
            leaves.append(a)
    _, treedef = _flatten(template)
    return jax.tree.unflatten(treedef, leaves)


def restore_to_sharding(path: str, template: Any, shardings: Any) -> Any:
    """Restore + device_put each leaf to the matching sharding pytree."""
    host = load_pytree(path, template)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), host, shardings)


def reshard_islands(state: Any, n_new: int) -> Any:
    """Elastically change the island count of a stacked ColonyState.

    Shrink: keep the best n_new islands (by best_len). Grow: tile existing
    islands round-robin and decorrelate their RNG keys.
    """
    lens = np.asarray(state.best_len)
    n_old = lens.shape[0]
    if n_new <= n_old:
        keep = np.argsort(lens)[:n_new]
        return jax.tree.map(lambda x: x[keep], state)
    reps = [i % n_old for i in range(n_new)]
    out = jax.tree.map(lambda x: x[np.asarray(reps)], state)
    # decorrelate keys of the copies
    new_keys = jax.vmap(jax.random.fold_in)(
        out.key, jax.numpy.arange(n_new, dtype=jax.numpy.uint32))
    return out._replace(key=new_keys)


class CheckpointManager:
    """Step-numbered checkpoints with retention and optional async writes."""

    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue[tuple[str, Any, int]]" = queue.Queue(maxsize=1)
        self._async = async_write
        self._err: Optional[BaseException] = None
        if async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:09d}.npz")

    def _worker(self) -> None:
        while True:
            path, tree, step = self._q.get()
            try:
                save_pytree(path, tree, step)
                self._gc()
            except BaseException as e:  # surfaced on next save/wait
                self._err = e
            finally:
                self._q.task_done()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: max(0, len(steps) - self.keep)]:
            try:
                os.remove(self._path(s))
            except OSError:
                pass

    def all_steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            if f.startswith("ckpt_") and f.endswith(".npz"):
                out.append(int(f[5:-4]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any) -> None:
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("async checkpoint write failed") from err
        # Materialise on host *now* so the caller may mutate its state.
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self._async:
            self._q.put((self._path(step), host, step))
        else:
            save_pytree(self._path(step), host, step)
            self._gc()

    def wait(self) -> None:
        if self._async:
            self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError("async checkpoint write failed") from err

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, int]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self._path(step)
        if shardings is not None:
            return restore_to_sharding(path, template, shardings), step
        return load_pytree(path, template), step
