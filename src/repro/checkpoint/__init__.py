from .checkpoint import (CheckpointManager, load_pytree, reshard_islands,
                         restore_to_sharding, save_pytree)

__all__ = ["CheckpointManager", "load_pytree", "save_pytree",
           "restore_to_sharding", "reshard_islands"]
