"""Sparse colony step: the dense ``core.aco.colony_step`` control flow on
the O(n·k) paged representation.

One iteration = construct (or Partial-ACO-mutate) m tours over candidate
pages, track the best, deposit per variant, clamp (MMAS) / locally decay
(ACS) — the exact step order and key discipline of the dense step, so at
k = n-1 (every edge on a candidate page, overflow empty) the trajectories
coincide bit-for-bit for AS/MMAS/ACS (tests/test_sparse.py).

Route validation happens once, up front, through the single typed
rejection point ``kernels.ops.check_kernel_route`` — roulette selection
(needs full-row CDFs), dense-matrix local search, and per-instance Hyper
operands raise ``UnsupportedKernelRoute`` with one actionable line
instead of failing deep in a trace.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aco as dense_aco
from repro.core import quant, tsp

from . import construct, pheromone, store
from .store import SparseColonyState, SparseProblem

Array = jax.Array


def check_sparse_route(cfg: dense_aco.ACOConfig, hyper: bool = False,
                       masked: bool = False) -> None:
    """Reject sparse x feature combinations the route cannot serve."""
    from repro.kernels import ops as kops
    kops.check_kernel_route(masked=masked, hyper=hyper, sparse=True,
                            selection=cfg.selection,
                            local_search=cfg.local_search,
                            construction=cfg.construction,
                            tau_dtype=cfg.tau_dtype)


def make_sparse_problem_cfg(instance: tsp.TSPInstance,
                            cfg: dense_aco.ACOConfig,
                            n_pad: Optional[int] = None) -> SparseProblem:
    return store.make_sparse_problem(instance, cfg.sparse_k, n_pad)


def init_sparse_colony(instance: tsp.TSPInstance, cfg: dense_aco.ACOConfig,
                       seed: Optional[int] = None,
                       n_pad: Optional[int] = None) -> SparseColonyState:
    """Fresh sparse state: tau0 on every page, empty overflow slots.

    tau0 comes from the same NN-tour formulas as the dense
    ``aco.initial_tau`` (computed row-wise, no (n, n) matrix).  Partial-ACO
    construction needs a valid running best to mutate, so it seeds
    best_tour/best_len with the NN tour itself; the standard route starts
    from the identity tour at +inf, exactly like the dense init.
    """
    n = instance.n
    n_pad = n if n_pad is None else n_pad
    # page width, NOT clamped to n-1: the problem pages keep the full
    # ``sparse_k`` width with surplus self-sentinel columns (store.
    # build_candidates), and tau must line up column-for-column.
    k = max(1, cfg.sparse_k)
    tau0 = store.sparse_initial_tau(instance, cfg)
    if cfg.construction == "partial":
        nn_tour, nn_len = store.sparse_nearest_neighbour_tour(instance)
        best_tour = jnp.asarray(
            np.concatenate([nn_tour,
                            np.arange(n, n_pad, dtype=np.int32)]))
        best_len = jnp.asarray(np.float32(nn_len))
    else:
        best_tour = jnp.arange(n_pad, dtype=jnp.int32)
        best_len = jnp.asarray(np.float32(np.inf))
    o = cfg.sparse_overflow
    return SparseColonyState(
        tau=dense_aco.make_tau(jnp.full((n_pad, k), tau0, jnp.float32),
                               cfg),
        tau_def=jnp.asarray(np.float32(tau0)),
        ovf_city=jnp.full((n_pad, o), store.OVF_EMPTY, jnp.int32),
        ovf_tau=_make_ovf_tau(jnp.zeros((n_pad, o), jnp.float32), cfg),
        best_tour=best_tour,
        best_len=best_len,
        iteration=jnp.asarray(0, jnp.int32),
        key=jax.random.PRNGKey(cfg.seed if seed is None else seed),
    )


def _make_ovf_tau(ovf_f32, cfg: dense_aco.ACOConfig):
    """Overflow pages follow the store dtype but never carry an
    error-feedback residual: slots churn (adopt/evict) so a carried
    per-slot residual would attribute one edge's error to another."""
    if not quant.is_quantised(cfg.tau_dtype):
        return ovf_f32
    return quant.quantise(ovf_f32, cfg.tau_dtype)


@partial(jax.jit, static_argnames=("cfg", "ewt"))
def sparse_colony_step(problem: SparseProblem, state: SparseColonyState,
                       cfg: dense_aco.ACOConfig,
                       ewt: str) -> tuple:
    """One full sparse ACO iteration; mirrors ``aco.colony_step``.

    ``ewt`` (static): TSPLIB rounding rule for the lazy off-list
    distances; candidate-page distances are precomputed.

    Returns (new_state, it_best_len); with ``cfg.metrics``, additionally
    an ``obs.StepMetrics`` (tau stats over the (n, k) pages, overflow
    adoption/eviction counts from the ovf_city delta) — read-only
    reductions, bitwise-neutral to the state trajectory (DESIGN.md §13).
    """
    n = problem.n
    m = cfg.num_ants(n)
    n_act = problem.n_actual
    check_sparse_route(cfg, masked=n_act is not None)
    quantised = quant.is_quantised(cfg.tau_dtype)
    if quantised:
        # extra split feeds the two quantise-on-store steps (tau pages and
        # overflow pages); the fp32 branch keeps today's two-way split.
        key, k_tour, k_q = jax.random.split(state.key, 3)
    else:
        key, k_tour = jax.random.split(state.key)
        k_q = None

    if cfg.construction == "partial":
        res = construct.partial_tours(
            k_tour, problem, state.tau, state.ovf_city, state.ovf_tau,
            state.best_tour, state.best_len, m, cfg.partial_window,
            cfg.selection, cfg.alpha, cfg.beta, ewt,
            use_pallas=cfg.use_pallas, draw_mode=cfg.draw_mode)
    else:
        res = construct.construct_sparse_tours(
            k_tour, problem, state.tau, state.ovf_city, state.ovf_tau, m,
            cfg.selection, cfg.alpha, cfg.beta, ewt,
            use_pallas=cfg.use_pallas, draw_mode=cfg.draw_mode)

    it_best_idx = jnp.argmin(res.lengths)
    it_best_len = res.lengths[it_best_idx]
    it_best_tour = res.tours[it_best_idx]
    if cfg.construction == "partial":
        # delta lengths are float32-approximate; re-measure the candidate
        # exactly before accepting, so the best sequence is monotone.
        it_best_len = store.sparse_tour_length(
            problem, it_best_tour[None, :], ewt, n_act)[0]

    improved = it_best_len < state.best_len
    best_len = jnp.where(improved, it_best_len, state.best_len)
    best_tour = jnp.where(improved, it_best_tour, state.best_tour)

    rho, q = cfg.rho, cfg.q
    if cfg.variant == "as":
        dep_tours, dep_w = res.tours, q / res.lengths
    elif cfg.variant == "mmas":
        if cfg.mmas_best == "global":
            dep_tours, dep_w = best_tour[None, :], (q / best_len)[None]
        else:
            dep_tours, dep_w = it_best_tour[None, :], (q / it_best_len)[None]
    elif cfg.variant == "acs":
        dep_tours = best_tour[None, :]
        dep_w = (rho * q / best_len)[None]
    else:
        raise ValueError(f"unknown variant {cfg.variant}")

    adopt = cfg.variant in ("mmas", "acs") and cfg.sparse_overflow > 0
    # Transient fp32 views for the update/clamp path (identity for fp32);
    # construction above consumed the resident payload directly.
    tau_full = quant.dequantise(state.tau) if quantised else state.tau
    ovf_full = quant.dequantise(state.ovf_tau) if quantised else state.ovf_tau
    tau, tau_def, ovf_city, ovf_tau = pheromone.update_sparse(
        tau_full, state.tau_def, state.ovf_city, ovf_full,
        problem.cand, dep_tours, dep_w, rho, adopt, n_act)

    n_eff = n if n_act is None else n_act
    clamp = None
    if cfg.variant == "mmas":
        tau_max = q / (rho * best_len)
        tau_min = tau_max / (2.0 * n_eff)
        tau = jnp.clip(tau, tau_min, tau_max)
        tau_def = jnp.clip(tau_def, tau_min, tau_max)
        ovf_tau = jnp.clip(ovf_tau, tau_min, tau_max)
        clamp = (tau_min, tau_max)
    elif cfg.variant == "acs":
        tau0 = q / (n_eff * jnp.maximum(best_len, 1e-9))
        tau, tau_def, ovf_tau = pheromone.local_update_acs_sparse(
            tau, tau_def, ovf_tau, problem.cand, res.tours, cfg.xi, tau0,
            n_act)

    # Quantise-on-store: pages and overflow each requantise with their
    # own key; metrics below read the exact fp32 tau of this step.
    tau_store, ovf_store = tau, ovf_tau
    if quantised:
        k_q1, k_q2 = jax.random.split(k_q)
        tau_store = quant.requantise(
            tau, state.tau, cfg.tau_dtype,
            quant.round_key(cfg.tau_round, k_q1))
        ovf_store = quant.requantise(
            ovf_tau, state.ovf_tau, cfg.tau_dtype,
            quant.round_key(cfg.tau_round, k_q2))

    new_state = SparseColonyState(tau_store, tau_def, ovf_city, ovf_store,
                                  best_tour, best_len,
                                  state.iteration + 1, key)
    if not cfg.metrics:
        return new_state, it_best_len
    from repro.obs import metrics as obs_metrics
    # overflow churn from the ovf_city delta: a slot whose city changed to
    # a non-empty value was adopted; if it previously held another city,
    # that city was evicted to make room (pheromone.update_sparse's
    # evict-weakest-iff-stronger rule).
    changed = (ovf_city != state.ovf_city)
    adopted = jnp.sum((changed & (ovf_city != store.OVF_EMPTY))
                      .astype(jnp.int32))
    evicted = jnp.sum((changed & (state.ovf_city != store.OVF_EMPTY)
                       & (ovf_city != store.OVF_EMPTY)).astype(jnp.int32))
    mets = obs_metrics.step_metrics(
        res.lengths, it_best_len, best_len, improved, tau, clamp,
        ovf_adopted=adopted, ovf_evicted=evicted)
    return new_state, it_best_len, mets


def run_sparse(instance: tsp.TSPInstance, cfg: dense_aco.ACOConfig,
               state: Optional[SparseColonyState] = None,
               problem: Optional[SparseProblem] = None) -> SparseColonyState:
    """Python-loop driver for one sparse colony (jitted inner step)."""
    check_sparse_route(cfg)
    if problem is None:
        problem = make_sparse_problem_cfg(instance, cfg)
    if state is None:
        state = init_sparse_colony(instance, cfg)
    ewt = instance.edge_weight_type
    for _ in range(int(state.iteration), cfg.iterations):
        state = sparse_colony_step(problem, state, cfg, ewt)[0]
    return state
