"""Sparse/paged problem representation: O(n*k) storage, no dense rows.

The dense pipeline keeps three resident (n, n) float32 tensors per colony
(distance, eta, pheromone) plus a fourth transient one (choice) — a hard
O(n^2) memory wall that caps instances far below the paper's 2392-city
ceiling.  This module is the ACO analogue of a paged KV cache (DESIGN.md
§12): every resident tensor is candidate-list-restricted to (n, k):

- ``SparseProblem``: per-city candidate lists (``cand``, the k nearest
  neighbours by TSPLIB-rounded distance, deterministic index tie-break)
  with distance and eta stored **only on candidate edges**, plus the raw
  (n, 2) coordinates so any off-list distance can be recomputed lazily in
  O(1) — the "page fault" path;
- ``SparseColonyState``: pheromone held only on candidate edges
  (``tau`` (n, k)) plus a scalar **off-list default trail** ``tau_def``
  (MMAS clamping makes a shared off-list level exact-enough by
  construction: unvisited off-list edges all decay to tau_min) and a
  bounded per-city **overflow page** (``ovf_city``/``ovf_tau``, O slots)
  that adopts off-list edges the best tours actually use.

Bitwise contract: every stored **real** candidate value (distance, eta,
tau0) is produced by the same arithmetic as the dense route's matrix
entry — float64 TSPLIB rounding (``tsp.pairwise_distances``) cast to
float32, ``1/max(d, 1e-10)`` eta, the same nearest-neighbour-tour tau0 —
so the sparse route with k = n-1 reproduces the dense route bit-for-bit
(tests/test_sparse.py).  The one exception is surplus **self-sentinel**
slots (page positions beyond a row's n-1 real neighbours, and every
phantom-row slot): they hold cand_dist = 1.0 — not the dense diagonal's
dist[i, i] = 0.0 — purely so the derived eta stays finite.  This never
surfaces: self entries are always visited-masked during selection and
``pair_lookup`` is never called with a == b, but callers must not rely on
sentinel slots mirroring dense matrix entries.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tsp

Array = jax.Array

OVF_EMPTY = -1          # ovf_city sentinel: slot not adopted


class SparseProblem(NamedTuple):
    """Device-resident constants for one candidate-list-restricted instance.

    ``coords`` is the only per-city dense object (n, 2); everything else is
    (n, k).  ``n_actual`` follows the dense Problem convention (DESIGN.md
    §8): None for ordinary instances, a traced () int32 scalar for padded
    instances (phantom cities never appear in any candidate list —
    tsp.nn_lists masks them to the self sentinel).  The TSPLIB rounding
    rule (edge_weight_type) is *static* and travels next to the problem as
    a plain string through the jitted entry points, not inside the pytree.
    """
    coords: Array          # (n, 2) float32
    cand: Array            # (n, k) int32 candidate city ids (self = sentinel)
    cand_dist: Array       # (n, k) float32, bitwise == dense dist at (i, cand)
    cand_eta: Array        # (n, k) float32, bitwise == dense eta at (i, cand)
    n_actual: Optional[Array] = None   # () int32, or None (unpadded)

    @property
    def n(self) -> int:
        return int(self.cand.shape[-2])

    @property
    def k(self) -> int:
        return int(self.cand.shape[-1])


class SparseColonyState(NamedTuple):
    """Paged pheromone state + the usual best-tracking scalars."""
    tau: Array             # (n, k) trail on candidate edges
    tau_def: Array         # () off-list default trail (clamped level)
    ovf_city: Array        # (n, O) int32 adopted off-list cities (-1 empty)
    ovf_tau: Array         # (n, O) float32 adopted off-list trail
    best_tour: Array       # (n,) int32
    best_len: Array        # () float32
    iteration: Array       # () int32
    key: Array             # PRNG key


def _pairwise_f32(xy: np.ndarray, rows: np.ndarray, ewt: str) -> np.ndarray:
    """(len(rows), n) float32 distance rows, bitwise == dense matrix rows."""
    d = tsp.pairwise_distances(xy[rows], xy, ewt)
    d[np.arange(len(rows)), rows] = 0.0      # diagonal convention
    return d.astype(np.float32)


def build_candidates(instance: tsp.TSPInstance, k: int,
                     chunk: int = 256) -> tuple[np.ndarray, np.ndarray]:
    """(n, k) candidate ids + distances without materialising (n, n).

    Distance rows are produced in ``chunk``-row blocks (transient
    O(chunk * n), resident O(n * k)); candidates are the k nearest by the
    float32-cast distance with deterministic index tie-breaking — the same
    ordering rule as ``tsp.nn_lists`` (stable argsort), so small instances
    agree with the dense builder.  Rows whose real neighbour count n-1 is
    below ``k`` fill surplus positions with the row's own index (the
    always-visited self sentinel; never selectable).
    """
    if instance.coords is None:
        raise ValueError(
            "sparse representation needs coordinates; EXPLICIT "
            "distance-matrix instances must run the dense route")
    xy = np.asarray(instance.coords, np.float64)
    n = instance.n
    kk = max(1, min(k, n - 1))
    cand = np.empty((n, k), np.int32)
    cdist = np.empty((n, k), np.float32)
    for lo in range(0, n, chunk):
        rows = np.arange(lo, min(lo + chunk, n))
        d = _pairwise_f32(xy, rows, instance.edge_weight_type)
        d[np.arange(len(rows)), rows] = np.inf      # exclude self
        order = np.argsort(d, axis=-1, kind="stable")[:, :kk]
        cand[rows, :kk] = order
        cdist[rows, :kk] = np.take_along_axis(d, order, axis=-1)
        if kk < k:                                   # surplus -> self sentinel
            cand[rows, kk:] = rows[:, None]
            cdist[rows, kk:] = 1.0
    return cand, cdist


def make_sparse_problem(instance: tsp.TSPInstance, k: int,
                        n_pad: Optional[int] = None,
                        chunk: int = 256) -> SparseProblem:
    """Build the O(n*k) problem pages, optionally padded to ``n_pad``.

    Phantom rows (>= instance.n) are entirely self-sentinel candidates
    with eta 0 — a phantom city is never selectable and never offers
    candidates (satellite contract: phantoms never appear in a candidate
    list).  ``n_actual`` is attached whenever padding is requested, like
    ``solver.batch.padded_problem`` does for the dense route.
    """
    n = instance.n
    n_pad = n if n_pad is None else n_pad
    if n_pad < n:
        raise ValueError(f"n_pad={n_pad} < instance size {n}")
    cand, cdist = build_candidates(instance, k, chunk)
    eta = (np.float32(1.0) / np.maximum(cdist, np.float32(1e-10))).astype(
        np.float32)
    coords = np.asarray(instance.coords, np.float32)
    if n_pad > n:
        pad_idx = np.arange(n, n_pad, dtype=np.int32)
        cand = np.concatenate(
            [cand, np.broadcast_to(pad_idx[:, None], (n_pad - n, k)).copy()])
        cdist = np.concatenate([cdist, np.ones((n_pad - n, k), np.float32)])
        eta = np.concatenate([eta, np.zeros((n_pad - n, k), np.float32)])
        coords = np.concatenate([coords, np.zeros((n_pad - n, 2), np.float32)])
    n_act = jnp.asarray(n, jnp.int32) if n_pad > n else None
    return SparseProblem(jnp.asarray(coords), jnp.asarray(cand),
                         jnp.asarray(cdist), jnp.asarray(eta), n_act)


# --------------------------------------------------------------- lazy pages

def lazy_rows(coords: Array, cur: Array, ewt: str) -> Array:
    """(m, n) float32 distances from cities ``cur`` to every city, computed
    on the fly from coordinates — the page-fault path for fallback steps
    and off-list lookups.  float32 arithmetic: only consumed where no
    bitwise contract applies (off-list edges cannot exist at k = n-1)."""
    diff = coords[cur][:, None, :] - coords[None, :, :]
    return _round_ewt(diff, ewt)


def lazy_pair(coords: Array, a: Array, b: Array, ewt: str) -> Array:
    """Elementwise float32 distances between city arrays of equal shape."""
    diff = coords[a] - coords[b]
    return _round_ewt(diff, ewt)


def _round_ewt(diff: Array, ewt: str) -> Array:
    sq = (diff * diff).sum(-1)
    if ewt == "EUC_2D":
        return jnp.rint(jnp.sqrt(sq))
    if ewt == "CEIL_2D":
        return jnp.ceil(jnp.sqrt(sq))
    if ewt == "ATT":
        rij = jnp.sqrt(sq / 10.0)
        tij = jnp.rint(rij)
        return jnp.where(tij < rij, tij + 1.0, tij)
    if ewt == "RAW":
        return jnp.sqrt(sq)
    raise ValueError(f"unsupported edge_weight_type {ewt}")


def pair_lookup(problem: SparseProblem, a: Array, b: Array,
                ewt: str) -> Array:
    """Distance of arbitrary city pairs: candidate page hit -> stored
    (dense-bitwise) value; miss -> lazy recompute.  a/b same shape."""
    rows = problem.cand[a]                       # (..., k)
    eq = rows == b[..., None]
    found = eq.any(-1)
    pos = jnp.argmax(eq, -1)
    on = jnp.take_along_axis(problem.cand_dist[a], pos[..., None], -1)[..., 0]
    return jnp.where(found, on, lazy_pair(problem.coords, a, b, ewt))


def sparse_tour_length(problem: SparseProblem, tours: Array, ewt: str,
                       n_actual: Optional[Array] = None) -> Array:
    """Closed-tour lengths for (m, n) tours from the sparse pages only.

    Mirrors ``tsp.tour_length`` masking semantics; every edge distance is
    a candidate-page hit or a lazy recompute.
    """
    nxt = jnp.roll(tours, -1, axis=-1)
    if n_actual is not None:
        idx = jnp.arange(tours.shape[-1], dtype=jnp.int32)
        nxt = jnp.where(idx == n_actual - 1, tours[..., :1], nxt)
    d = pair_lookup(problem, tours, nxt, ewt)
    if n_actual is not None:
        idx = jnp.arange(tours.shape[-1], dtype=jnp.int32)
        d = jnp.where(idx < n_actual, d, 0.0)
    return tsp.edge_sum(d)


# ----------------------------------------------------------- init / metrics

def sparse_nearest_neighbour_tour(instance: tsp.TSPInstance,
                                  start: int = 0) -> tuple[np.ndarray, float]:
    """Greedy NN tour from coordinate rows (no (n, n) matrix), bitwise the
    dense ``tsp.nearest_neighbour_tour`` result: each row is the same
    float64-rounded-then-float32 values the dense matrix holds, and the
    length is summed over the same float32 edge array."""
    xy = np.asarray(instance.coords, np.float64)
    n = instance.n
    ewt = instance.edge_weight_type
    visited = np.zeros(n, dtype=bool)
    tour = np.empty(n, dtype=np.int32)
    cur = start
    tour[0] = cur
    visited[cur] = True
    for i in range(1, n):
        row = _pairwise_f32(xy, np.asarray([cur]), ewt)[0]
        cur = int(np.argmin(np.where(visited, np.inf, row)))
        tour[i] = cur
        visited[cur] = True
    # Same float32 edge array (and the same numpy pairwise .sum()) as the
    # dense ``dist[tour, roll(tour, -1)].sum()`` — bitwise-equal length.
    edges = np.empty(n, np.float32)
    nxt = np.roll(tour, -1)
    for lo in range(0, n, 256):
        hi = min(lo + 256, n)
        h = hi - lo
        edges[lo:hi] = tsp.pairwise_distances(
            xy[tour[lo:hi]], xy[nxt[lo:hi]], ewt
        )[np.arange(h), np.arange(h)].astype(np.float32)
    return tour, float(edges.sum())


def sparse_initial_tau(instance: tsp.TSPInstance, cfg) -> float:
    """tau0 = m/C_nn (AS), 1/(rho C_nn) (MMAS), 1/(n C_nn) (ACS) — the same
    formulas as ``aco.initial_tau`` with C_nn from the row-wise NN tour."""
    _, c_nn = sparse_nearest_neighbour_tour(instance)
    n = instance.n
    m = cfg.num_ants(n)
    if cfg.variant == "mmas":
        return 1.0 / (cfg.rho * c_nn)
    if cfg.variant == "acs":
        return 1.0 / (n * c_nn)
    return m / c_nn


def resident_bytes(problem: SparseProblem,
                   state: SparseColonyState) -> int:
    """Total device-resident bytes of the sparse representation."""
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree.leaves((problem, state)))


def dense_resident_bytes(n: int) -> int:
    """What the dense route keeps resident for one colony: dist + eta +
    tau, three (n, n) float32 tensors (the transient (n, n) choice matrix
    and (m, n) construction tensors excluded from both sides)."""
    return 3 * n * n * 4


@dataclasses.dataclass(frozen=True)
class SparseBatchMeta:
    """Static facts a sparse bucket shares (DESIGN.md §12): one rounding
    rule and one candidate width per compiled program."""
    ewt: str
    k: int
    n_pad: int
