"""Sparse pheromone update: O(n·k) evaporation, candidate-page deposits,
bounded overflow-slot adoption for off-list best-tour edges.

Layout recap (DESIGN.md §12): trail lives at ``tau`` (n, k) on candidate
edges, at ``ovf_tau`` (n, O) on adopted off-list edges, and at the scalar
``tau_def`` for every other edge.  The update mirrors the dense
``pheromone.update`` exactly on candidate edges:

- evaporation is the same elementwise ``(1 - rho) *`` scale — O(n·k+n·O+1)
  instead of O(n²);
- deposits scatter-add onto candidate positions in two passes (forward
  edges into row f, reverse edges into row t), then one add — the same
  accumulation structure as the dense ``d + d.T``, so at k = n-1 (every
  edge on-list, overflow empty) the resulting tau is bitwise the dense
  tau (tests/test_sparse.py).  An edge whose target is off its row's
  candidate list contributes a bitwise-identity zero add instead (found
  mask), and is streamed to the adoption pass;
- adoption (single-deposit-tour variants, MMAS/ACS): a ``lax.scan`` over
  the deposit tour's n edges gives each off-list edge a chance to claim an
  overflow slot on its endpoint rows — match adds, a free slot adopts at
  ``tau_def + w`` (the trail an off-list edge holds after this step's
  evaporation, plus its deposit), a full page evicts the weakest slot only
  if the newcomer is stronger.  AS deposits m whole tours; scanning m·n
  edges is not O(n·k), so the AS route drops unadoptable off-list deposits
  (the MMAS clamp bounds the resulting error; AS is not the at-scale
  variant).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import pheromone as dense_ph

from .store import OVF_EMPTY

Array = jax.Array


def _positions(cand: Array, rows: Array, targets: Array
               ) -> tuple[Array, Array]:
    """For each (row, target) pair: (found, position of target in
    cand[row]).  Position is 0 when absent — callers must mask."""
    eq = cand[rows] == targets[..., None]
    return eq.any(-1), jnp.argmax(eq, -1).astype(jnp.int32)


def deposit_sparse(cand: Array, tours: Array, w: Array,
                   n_actual: Optional[Array] = None,
                   ant_chunk: Optional[int] = None) -> tuple[Array, Array]:
    """Candidate-page deposit for (m, n) tours with (m,) weights.

    Returns (dep (n, k), off (m*n,)) where ``off`` carries the weight of
    each *forward* edge that is off its row's candidate list (0 for
    on-list / phantom edges) — the adoption stream.

    Accumulation order matches the dense ``deposit_scatter`` exactly:
    forward scatters run in the same edge-stream order (one scatter over
    all m·n edges whenever the (m·n, k) position gather fits a small
    transient budget, per-ant scan chunks beyond it — within-stream order
    is preserved either way), reverse scatters likewise, then one
    elementwise add — the dense ``d + d.T``.
    """
    n, k = cand.shape
    f, t = dense_ph.tour_edges(tours, n_actual)
    wrep = dense_ph.edge_weights(tours, w, n_actual).reshape(f.shape)
    m = f.shape[0]
    if ant_chunk is None:
        ant_chunk = m if m * f.shape[1] * k <= 2 ** 22 else 1
    pad = (-m) % ant_chunk
    if pad:
        f = jnp.concatenate([f, jnp.zeros((pad, f.shape[1]), f.dtype)])
        t = jnp.concatenate([t, jnp.zeros((pad, t.shape[1]), t.dtype)])
        wrep = jnp.concatenate(
            [wrep, jnp.zeros((pad, wrep.shape[1]), wrep.dtype)])

    def body(carry, ft):
        d1, d2 = carry
        fc, tc, wc = ft
        fr, tr, wr = fc.ravel(), tc.ravel(), wc.ravel()
        fwd_found, fwd_pos = _positions(cand, fr, tr)
        rev_found, rev_pos = _positions(cand, tr, fr)
        d1 = d1.at[fr, fwd_pos].add(jnp.where(fwd_found, wr, 0.0))
        d2 = d2.at[tr, rev_pos].add(jnp.where(rev_found, wr, 0.0))
        return (d1, d2), jnp.where(fwd_found, 0.0, wr)

    nc = f.shape[0] // ant_chunk
    zeros = jnp.zeros((n, k), jnp.float32)
    (d1, d2), off = jax.lax.scan(
        body, (zeros, zeros),
        (f.reshape(nc, ant_chunk, -1), t.reshape(nc, ant_chunk, -1),
         wrep.reshape(nc, ant_chunk, -1)))
    return d1 + d2, off.ravel()[: m * f.shape[1]]


def adopt_offlist(cand: Array, ovf_city: Array, ovf_tau: Array,
                  tour: Array, w: Array, tau_def: Array,
                  n_actual: Optional[Array] = None
                  ) -> tuple[Array, Array]:
    """Give each off-list edge of one deposit tour a bounded overflow slot.

    ``tour`` (n,) with scalar weight ``w``; both endpoint rows of every
    off-list edge try to adopt.  Rules per row page (O slots): an existing
    slot for the city adds ``w``; else a free slot (OVF_EMPTY) adopts at
    ``tau_def + w`` — tau_def is the already-evaporated default, i.e. the
    trail the edge held as an anonymous off-list edge; else the weakest
    slot is evicted iff the newcomer's value beats it.  One lax.scan over
    the n edges: O(n·O) work, no data-dependent shapes.
    """
    f, t = dense_ph.tour_edges(tour[None, :], n_actual)
    wrep = dense_ph.edge_weights(tour[None, :],
                                 jnp.asarray([w], jnp.float32), n_actual)
    f, t, wrep = f[0], t[0], wrep.reshape(-1)

    def one_dir(oc, ot, row, city, we):
        page_c, page_t = oc[row], ot[row]
        onlist = (cand[row] == city).any()
        want = (we > 0) & ~onlist & (city != row)
        match = page_c == city
        free = page_c == OVF_EMPTY
        newval = tau_def + we
        j_match = jnp.argmax(match)
        j_free = jnp.argmax(free)
        j_min = jnp.argmin(ot[row])
        j = jnp.where(match.any(), j_match,
                      jnp.where(free.any(), j_free, j_min))
        act = want & (match.any() | free.any() | (newval > page_t[j_min]))
        val = jnp.where(match.any(), page_t[j] + we, newval)
        oc = oc.at[row, j].set(jnp.where(act, city, page_c[j]))
        ot = ot.at[row, j].set(jnp.where(act, val, page_t[j]))
        return oc, ot

    def body(carry, e):
        oc, ot = carry
        fe, te, we = e
        oc, ot = one_dir(oc, ot, fe, te, we)
        oc, ot = one_dir(oc, ot, te, fe, we)
        return (oc, ot), None

    (ovf_city, ovf_tau), _ = jax.lax.scan(
        body, (ovf_city, ovf_tau), (f, t, wrep))
    return ovf_city, ovf_tau


def update_sparse(tau: Array, tau_def: Array, ovf_city: Array,
                  ovf_tau: Array, cand: Array, tours: Array, w: Array,
                  rho, adopt: bool,
                  n_actual: Optional[Array] = None
                  ) -> tuple[Array, Array, Array, Array]:
    """Full sparse pheromone update: evaporation + deposit (+ adoption).

    ``adopt`` (static): run the overflow-adoption scan over the deposit
    tours' edges — callers enable it for single-tour deposit variants
    (MMAS/ACS) when overflow slots exist.
    """
    dep, _ = deposit_sparse(cand, tours, w, n_actual)
    tau = dense_ph.evaporate(tau, rho) + dep
    tau_def = dense_ph.evaporate(tau_def, rho)
    ovf_tau = dense_ph.evaporate(ovf_tau, rho)
    if adopt and ovf_city.shape[-1] > 0:
        # adopted deposits also land on overflow pages that already track
        # the edge; scan every deposit tour (1 for MMAS/ACS).
        def body(carry, tw):
            oc, ot = carry
            tr, we = tw
            oc, ot = adopt_offlist(cand, oc, ot, tr, we, tau_def, n_actual)
            return (oc, ot), None

        (ovf_city, ovf_tau), _ = jax.lax.scan(
            body, (ovf_city, ovf_tau), (tours, w))
    return tau, tau_def, ovf_city, ovf_tau


def local_update_acs_sparse(tau: Array, tau_def: Array, ovf_tau: Array,
                            cand: Array, tours: Array, xi: float,
                            tau0: Array,
                            n_actual: Optional[Array] = None,
                            ant_chunk: int = 1
                            ) -> tuple[Array, Array, Array]:
    """ACS local rule on candidate edges: per-edge crossing counts then the
    order-independent closed form (1-xi)^c — bitwise the dense
    ``local_update_acs`` restricted to candidate entries (counts are exact
    small integers, so forward+reverse accumulation order is irrelevant).
    Off-list crossings are dropped (their shared tau_def cannot decay
    per-edge); uncrossed edges see factor 1.0 exactly — unchanged, as in
    the dense route.  Overflow pages keep their trail (crossing an adopted
    edge is rare and the MMAS-less ACS run bounds ovf_tau via
    evaporation).
    """
    n, k = cand.shape
    f, t = dense_ph.tour_edges(tours, n_actual)
    ew = jnp.ones(f.shape, tau.dtype)
    if n_actual is not None:
        idx = jnp.arange(f.shape[-1], dtype=jnp.int32)
        ew = jnp.where(idx[None, :] < n_actual, ew, 0.0)
    m = f.shape[0]
    pad = (-m) % ant_chunk
    if pad:
        f = jnp.concatenate([f, jnp.zeros((pad, f.shape[1]), f.dtype)])
        t = jnp.concatenate([t, jnp.zeros((pad, t.shape[1]), t.dtype)])
        ew = jnp.concatenate([ew, jnp.zeros((pad, ew.shape[1]), ew.dtype)])

    def body(counts, ft):
        fc, tc, wc = ft
        fr, tr, wr = fc.ravel(), tc.ravel(), wc.ravel()
        fwd_found, fwd_pos = _positions(cand, fr, tr)
        rev_found, rev_pos = _positions(cand, tr, fr)
        counts = counts.at[fr, fwd_pos].add(jnp.where(fwd_found, wr, 0.0))
        counts = counts.at[tr, rev_pos].add(jnp.where(rev_found, wr, 0.0))
        return counts, None

    nc = f.shape[0] // ant_chunk
    counts, _ = jax.lax.scan(
        body, jnp.zeros((n, k), tau.dtype),
        (f.reshape(nc, ant_chunk, -1), t.reshape(nc, ant_chunk, -1),
         ew.reshape(nc, ant_chunk, -1)))
    factor = jnp.power(jnp.asarray(1.0 - xi, tau.dtype), counts)
    tau = factor * tau + (1.0 - factor) * tau0
    return tau, tau_def, ovf_tau
