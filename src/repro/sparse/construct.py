"""Sparse tour construction: selection over k-wide candidate rows.

One construction step of the dense data-parallel strategy gathers an
(m, n) choice row per ant; here an ant sees only its current city's
candidate page — (m, k) pheromone/eta gathered from the (n, k) store,
extended by the city's O overflow slots (adopted off-list edges,
sparse/pheromone.py) — plus a lazily-computed nearest-unvisited fallback
for the steps where an ant has exhausted its whole candidate set.  No
(n, n) tensor exists on this route; per-step transients are (m, n)
(random draws, tabu) and (m, k+O).

Bitwise contract with the dense route at k = n-1 (tests/test_sparse.py):

- random draws are **full-width**: the same ``fold_in(kc, t)`` key draws
  the same (m, n) uniform/Gumbel tensor the dense selector draws, and the
  sparse step *gathers* it at candidate cities.  Which tensor depends on
  the route — the pure route mirrors the dense pure selectors (uniform for
  iroulette, Gumbel for gumbel), while ``use_pallas=True`` always draws
  uniforms because the kernel applies the per-mode transform itself, the
  same operand contract as the dense ``ops.tour_select_step`` (so sparse
  pallas matches *dense pallas* bitwise at k = n-1, and sparse pure
  matches dense pure).  Weighted scores at a city
  are then bitwise the dense scores (same tau/eta/mask values, same
  multiply order), so the argmax winner is the same city — candidate
  order only permutes positions, and argmax ties cannot arise among
  distinct positive scores;
- per-edge distances come from the candidate page (stored values are
  bitwise the dense matrix entries) and are assembled into the same
  (m, n) edge array the dense ``_finish`` builds, summed on the same
  axis — identical reduction order, identical lengths.

Partial-ACO (Chitty, "Applying ACO To Large Scale TSP Instances"): each
ant copies the running best tour and reconstructs only a bounded window
of w cities through the same candidate-page selection, so one iteration
costs O(m·w·k) + O(w·n) fallback transients instead of O(m·n·k) — the
route that keeps very large n inside a fixed per-iteration budget.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quant, sampling, strategies, tsp
from repro.core.strategies import TourResult

from . import store
from .store import SparseProblem

Array = jax.Array

_NEG_INF = -1e30


def _candidate_page(problem: SparseProblem, tau, ovf_city: Array,
                    ovf_tau, cur: Array, ewt: str
                    ) -> tuple[Array, Array, Optional[Array], Array, Array]:
    """Gather the extended candidate row for each ant's current city.

    Returns (cities, tau_row, tau_scale, eta_row, dist_row); all (m, k+O)
    except tau_scale.  Overflow slots are appended after the k candidates;
    empty slots map to the ant's own (always-visited) city, so every
    selection rule masks them to weight 0 — the same self-sentinel
    ``tsp.nn_lists`` uses for surplus positions.  Overflow eta/distances
    are lazy (float32 page-fault path): at k = n-1 every slot is empty, so
    the bitwise contract never sees a lazy value.

    Quantised stores (core/quant.py): ``tau``/``ovf_tau`` arrive as
    QuantTau pytrees; the gathered ``tau_row`` is then the raw int8/bf16
    payload and ``tau_scale`` the (m, k+O) per-row scales for int8
    (candidate and overflow columns each broadcast their own store's
    scale) — only the (m, K) transient is ever dequantised, never the
    resident pages.
    """
    quantised = isinstance(tau, quant.QuantTau)
    tau_store = tau.q if quantised else tau
    cities = problem.cand[cur]                       # (m, k)
    tau_row = tau_store[cur]
    tau_scale = None
    if quantised and tau.q.dtype == jnp.int8:
        tau_scale = jnp.broadcast_to(tau.scale[cur], tau_row.shape)
    eta_row = problem.cand_eta[cur]
    dist_row = problem.cand_dist[cur]
    o = ovf_city.shape[-1]
    if o:
        oc = ovf_city[cur]                           # (m, O)
        oc = jnp.where(oc >= 0, oc, cur[:, None]).astype(jnp.int32)
        od = store.lazy_pair(problem.coords, jnp.broadcast_to(
            cur[:, None], oc.shape), oc, ewt)
        oe = 1.0 / jnp.maximum(od, 1e-10)
        ovf_store = ovf_tau.q if quantised else ovf_tau
        cities = jnp.concatenate([cities, oc], axis=-1)
        tau_row = jnp.concatenate([tau_row, ovf_store[cur]], axis=-1)
        if tau_scale is not None:
            oscale = jnp.broadcast_to(ovf_tau.scale[cur],
                                      (oc.shape[0], o))
            tau_scale = jnp.concatenate([tau_scale, oscale], axis=-1)
        eta_row = jnp.concatenate([eta_row, oe], axis=-1)
        dist_row = jnp.concatenate([dist_row, od], axis=-1)
    return cities, tau_row, tau_scale, eta_row, dist_row


def _score(w: Array, rand_full: Array, cities: Array, ants: Array,
           selection: str) -> Array:
    """Selection scores over the masked candidate weights ``w`` (m, K).

    ``rand_full`` is the (m, n) full-width draw; gathering it at candidate
    cities makes a candidate's score bitwise the dense selector's score at
    that city (sampling.iroulette / sampling.gumbel semantics).
    """
    if selection == "greedy":
        return w
    r = rand_full[ants[:, None], cities]             # (m, K)
    if selection == "iroulette":
        return w * r
    if selection == "gumbel":
        logw = jnp.where(w > 0, jnp.log(jnp.maximum(w, 1e-38)), _NEG_INF)
        return logw + r
    raise ValueError(f"selection {selection!r} unsupported on sparse route")


def _draw(key: Array, m: int, n: int, selection: str,
          use_pallas: bool, draw_mode: str = "packed") -> Array:
    """The full-width (m, n) stochastic tensor for this step.

    Pure route: the same draw (same key, shape, dtype) the dense *pure*
    selector makes (sampling.iroulette / sampling.gumbel), so gathered
    entries match the dense pure route bit-for-bit.  Pallas route: the
    kernel consumes **uniforms** and applies the per-mode transform itself
    (tour_select._transform — the dense kernel contract, see
    ops.tour_select_step), so gumbel draws uniforms here and the
    uniform->gumbel map happens in-kernel; feeding it raw Gumbel samples
    would double-transform (negative samples clip to a constant).  Greedy
    ignores the values but the kernel's BlockSpecs still need a real
    (m, n) operand on the pallas route."""
    if selection == "greedy":
        if use_pallas:
            return jnp.zeros((m, n), jnp.float32)    # values ignored
        return jnp.zeros((1, 1), jnp.float32)        # unused
    if draw_mode == "counter":
        # Width-invariant (ant, city) counter bits (core/sampling.py):
        # gathered entries match the dense *counter* route bit-for-bit,
        # and the draw at a real pair is bucket-width independent — the
        # neighbour-routing exactness basis (DESIGN.md §16).
        if selection == "gumbel" and not use_pallas:
            return sampling.counter_gumbel(key, (m, n))
        return sampling.counter_uniform(key, (m, n), minval=1e-6,
                                        maxval=1.0)
    if selection == "gumbel" and not use_pallas:
        return jax.random.gumbel(key, (m, n), jnp.float32)
    return jax.random.uniform(key, (m, n), jnp.float32,
                              minval=1e-6, maxval=1.0)


def _fallback_nearest(problem: SparseProblem, cur: Array, visited: Array,
                      ewt: str, n_actual: Optional[Array]) -> Array:
    """Nearest unvisited city by lazy distance — the O(m·n) page-fault
    step, only reached when an ant's whole candidate set is visited."""
    rows = store.lazy_rows(problem.coords, cur, ewt)             # (m, n)
    bad = visited
    if n_actual is not None:
        idx = jnp.arange(rows.shape[-1], dtype=jnp.int32)
        bad = bad | (idx[None, :] >= n_actual)
    rows = jnp.where(bad, jnp.inf, rows)
    return jnp.argmin(rows, axis=-1).astype(jnp.int32)


class _SparseCarry(NamedTuple):
    cur: Array       # (m,)
    visited: Array   # (m, n) bool


@partial(jax.jit, static_argnames=("m", "selection", "alpha_beta", "ewt",
                                   "masked", "use_pallas", "draw_mode"))
def _construct_sparse(key: Array, problem: SparseProblem, tau: Array,
                      ovf_city: Array, ovf_tau: Array, n_actual_op: Array,
                      m: int, selection: str, alpha_beta: tuple,
                      ewt: str, masked: bool,
                      use_pallas: bool,
                      draw_mode: str = "packed") -> TourResult:
    alpha, beta = alpha_beta
    n = problem.n
    kp, kc = jax.random.split(key)
    n_act = n_actual_op if masked else None
    start = strategies.place_ants(kp, m, n, n_act)
    ants = jnp.arange(m)
    visited0 = jnp.zeros((m, n), jnp.bool_).at[ants, start].set(True)

    def body(st: _SparseCarry, t: Array):
        k_ = jax.random.fold_in(kc, t)
        cities, tau_row, tau_scale, eta_row, dist_row = _candidate_page(
            problem, tau, ovf_city, ovf_tau, st.cur, ewt)
        rand_full = _draw(k_, m, n, selection, use_pallas, draw_mode)
        if use_pallas:
            from repro.kernels import ops as kops
            pos, have = kops.sparse_select(
                tau_row, eta_row, cities, st.visited, rand_full,
                alpha, beta, selection, tau_scale=tau_scale)
        else:
            cmask = ~st.visited[ants[:, None], cities]
            tau_row_f = quant.dequantise_rows(tau_row, tau_scale)
            w = strategies.choice_matrix(tau_row_f, eta_row, alpha, beta) \
                * cmask
            have = w.sum(-1) > 0
            pos = jnp.argmax(
                _score(w, rand_full, cities, ants, selection),
                axis=-1).astype(jnp.int32)
        nxt_c = cities[ants, pos]
        d_c = dist_row[ants, pos]

        def page_fault(_):
            nxt_fb = _fallback_nearest(problem, st.cur, st.visited, ewt,
                                       n_act)
            return nxt_fb, store.lazy_pair(problem.coords, st.cur, nxt_fb,
                                           ewt)

        nxt_fb, d_fb = jax.lax.cond(
            jnp.all(have), lambda _: (nxt_c, d_c), page_fault, None)
        nxt = jnp.where(have, nxt_c, nxt_fb)
        dstep = jnp.where(have, d_c, d_fb)
        if masked:
            # phantom tail in fixed index order, zero-length edges — the
            # dense masked-emission invariant (DESIGN.md §8)
            nxt = jnp.where(t < n_act, nxt, t).astype(jnp.int32)
            dstep = jnp.where(t < n_act, dstep, 0.0)
        return _SparseCarry(nxt, st.visited.at[ants, nxt].set(True)), \
            (nxt, dstep)

    _, (steps, dsteps) = jax.lax.scan(
        body, _SparseCarry(start, visited0), jnp.arange(1, n))
    tours = jnp.concatenate([start[None, :], steps], axis=0).T
    tours = tours.astype(jnp.int32)
    # (m, n) per-edge array: position i = edge tours[i] -> tours[i+1],
    # closing edge last — the same array shape and sum axis as the dense
    # _finish / tsp.tour_length, so lengths reduce in the same order.
    edges = jnp.concatenate(
        [dsteps.T, jnp.zeros((m, 1), jnp.float32)], axis=-1)      # (m, n)
    idx = jnp.arange(n, dtype=jnp.int32)
    if masked:
        last = jnp.take_along_axis(
            tours, jnp.broadcast_to(n_act - 1, (m, 1)).astype(jnp.int32),
            axis=-1)[:, 0]
        d_close = store.pair_lookup(problem, last, tours[:, 0], ewt)
        edges = jnp.where(idx[None, :] == n_act - 1, d_close[:, None],
                          edges)
        edges = jnp.where(idx[None, :] < n_act, edges, 0.0)
    else:
        d_close = store.pair_lookup(problem, tours[:, -1], tours[:, 0], ewt)
        edges = edges.at[:, -1].set(d_close)
    return TourResult(tours, tsp.edge_sum(edges))


def construct_sparse_tours(key: Array, problem: SparseProblem, tau: Array,
                           ovf_city: Array, ovf_tau: Array, m: int,
                           selection: str, alpha: float, beta: float,
                           ewt: str, use_pallas: bool = False,
                           draw_mode: str = "packed") -> TourResult:
    """Build m complete tours from candidate pages only.

    tau (n, k) candidate-edge pheromone; ovf_city/ovf_tau (n, O) adopted
    off-list pages.  ``ewt`` (static) selects the lazy-distance rounding
    rule.  ``selection``: iroulette | gumbel | greedy (roulette needs a
    full-row CDF and is rejected upstream by check_kernel_route).
    """
    masked = problem.n_actual is not None
    n_act = problem.n_actual if masked else jnp.asarray(problem.n, jnp.int32)
    return _construct_sparse(key, problem, tau, ovf_city, ovf_tau, n_act,
                             m, selection, (float(alpha), float(beta)),
                             ewt, masked, use_pallas, draw_mode)


# ------------------------------------------------------------ Partial-ACO

@partial(jax.jit, static_argnames=("m", "window", "selection", "alpha_beta",
                                   "ewt", "use_pallas", "draw_mode"))
def _partial_impl(key: Array, problem: SparseProblem, tau: Array,
                  ovf_city: Array, ovf_tau: Array, best_tour: Array,
                  best_len: Array, m: int, window: int, selection: str,
                  alpha_beta: tuple, ewt: str,
                  use_pallas: bool,
                  draw_mode: str = "packed") -> TourResult:
    alpha, beta = alpha_beta
    n = problem.n
    ants = jnp.arange(m)
    kp, kc = jax.random.split(key)
    # window start positions: [1, n - window] (randint maxval is
    # exclusive) so the anchor (position s-1) and the reconnect city
    # (position s+window, mod n) both exist.
    s = jax.random.randint(kp, (m,), 1, n - window + 1, dtype=jnp.int32)
    wpos = s[:, None] + jnp.arange(window, dtype=jnp.int32)[None, :]
    wcities = best_tour[wpos]                                   # (m, w)
    anchor = best_tour[s - 1]                                   # (m,)
    reconnect = best_tour[(s + window) % n]                     # (m,)

    visited = jnp.ones((m, n), jnp.bool_)
    visited = visited.at[ants[:, None], wcities].set(False)

    def body(st: _SparseCarry, t: Array):
        k_ = jax.random.fold_in(kc, t)
        cities, tau_row, tau_scale, eta_row, dist_row = _candidate_page(
            problem, tau, ovf_city, ovf_tau, st.cur, ewt)
        rand_full = _draw(k_, m, n, selection, use_pallas, draw_mode)
        if use_pallas:
            from repro.kernels import ops as kops
            pos, have = kops.sparse_select(
                tau_row, eta_row, cities, st.visited, rand_full,
                alpha, beta, selection, tau_scale=tau_scale)
        else:
            cmask = ~st.visited[ants[:, None], cities]
            tau_row_f = quant.dequantise_rows(tau_row, tau_scale)
            w = strategies.choice_matrix(tau_row_f, eta_row, alpha, beta) \
                * cmask
            have = w.sum(-1) > 0
            pos = jnp.argmax(
                _score(w, rand_full, cities, ants, selection),
                axis=-1).astype(jnp.int32)
        nxt_c = cities[ants, pos]
        d_c = dist_row[ants, pos]

        def page_fault(_):
            nxt_fb = _fallback_nearest(problem, st.cur, st.visited, ewt,
                                       None)
            return nxt_fb, store.lazy_pair(problem.coords, st.cur, nxt_fb,
                                           ewt)

        nxt_fb, d_fb = jax.lax.cond(
            jnp.all(have), lambda _: (nxt_c, d_c), page_fault, None)
        nxt = jnp.where(have, nxt_c, nxt_fb)
        dstep = jnp.where(have, d_c, d_fb)
        return _SparseCarry(nxt, st.visited.at[ants, nxt].set(True)), \
            (nxt, dstep)

    _, (steps, dsteps) = jax.lax.scan(
        body, _SparseCarry(anchor, visited),
        jnp.arange(window, dtype=jnp.int32))
    new_window = steps.T.astype(jnp.int32)                      # (m, w)
    new_cost = dsteps.T.sum(-1) + store.pair_lookup(
        problem, new_window[:, -1], reconnect, ewt)

    # old segment cost: edges (s-1 -> s), ..., (s+w-1 -> s+w) of the best
    # tour, the w+1 edges the mutation replaces.
    opos = s[:, None] - 1 + jnp.arange(window + 1,
                                       dtype=jnp.int32)[None, :]
    oa = best_tour[opos]
    ob = best_tour[(opos + 1) % n]
    old_cost = store.pair_lookup(problem, oa, ob, ewt).sum(-1)

    tours = jnp.broadcast_to(best_tour[None, :], (m, n))
    tours = tours.at[ants[:, None], wpos].set(new_window)
    lengths = best_len - old_cost + new_cost
    return TourResult(tours.astype(jnp.int32), lengths)


def partial_tours(key: Array, problem: SparseProblem, tau: Array,
                  ovf_city: Array, ovf_tau: Array, best_tour: Array,
                  best_len: Array, m: int, window: int, selection: str,
                  alpha: float, beta: float, ewt: str,
                  use_pallas: bool = False,
                  draw_mode: str = "packed") -> TourResult:
    """Partial-ACO mutation: each ant reconstructs one bounded window of
    the running best tour via candidate-page selection.

    Returned lengths are delta-updated (best_len - old segment + new
    segment) in float32; the caller must re-measure the accepted best
    exactly (store.sparse_tour_length) before committing it — that exact
    re-measure is what makes the best-length sequence monotone
    non-worsening (tests/test_sparse.py).  Requires a *valid* best_tour
    (run_sparse seeds it with the row-wise NN tour), window <= n - 2, and
    an unpadded problem (masked instances are rejected upstream).
    """
    window = max(1, min(window, problem.n - 2))
    return _partial_impl(key, problem, tau, ovf_city, ovf_tau, best_tour,
                         best_len, m, window, selection,
                         (float(alpha), float(beta)), ewt, use_pallas,
                         draw_mode)
