"""Sparse/paged problem representation: candidate-list-restricted storage,
construction, and pheromone updates that never touch a dense (n, n) row.

Public surface:

- ``store``:      SparseProblem / SparseColonyState, builders, lazy
                  distance pages, resident-byte accounting
- ``construct``:  candidate-page tour construction + Partial-ACO mutation
- ``pheromone``:  O(n·k) evaporation/deposit, overflow-slot adoption
- ``aco``:        sparse_colony_step / run_sparse drivers

DESIGN.md §12 documents the layout, the off-list default-tau semantics,
the overflow adoption rule, and the supported-route matrix.
"""
from . import aco, construct, pheromone, store                  # noqa: F401
from .aco import (init_sparse_colony, run_sparse,               # noqa: F401
                  sparse_colony_step)
from .store import (SparseColonyState, SparseProblem,           # noqa: F401
                    make_sparse_problem, resident_bytes)
