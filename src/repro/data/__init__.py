from .pipeline import DataConfig, SyntheticLMData, tsp_batch_stream

__all__ = ["DataConfig", "SyntheticLMData", "tsp_batch_stream"]
