"""Deterministic, resumable data pipeline.

SyntheticLMData produces a reproducible token stream (threefry counter mode:
batch i is a pure function of (seed, i)) so that (a) restarts resume exactly
via the step cursor stored in the checkpoint and (b) every DP shard can
generate its own slice without a central reader — the same property a real
sharded webdataset reader provides, minus the disk. A mixed power-law
unigram + repeated-ngram structure gives the loss something learnable.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    ngram: int = 8          # repeated-block period (learnable structure)


class SyntheticLMData:
    """Stateless batch generator with an explicit cursor (checkpointable)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    @staticmethod
    def restore(cfg: DataConfig, state: dict) -> "SyntheticLMData":
        assert state["seed"] == cfg.seed, "data seed mismatch on restore"
        return SyntheticLMData(cfg, start_step=int(state["step"]))

    def batch_at(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + step) % 2**31)
        # power-law unigram distribution (zipf-ish), stable across steps
        ranks = np.arange(1, cfg.vocab + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        base = rng.choice(cfg.vocab, size=(cfg.global_batch, cfg.seq_len + 1),
                          p=probs)
        # inject repeated n-grams: second half of each period repeats first
        g = cfg.ngram
        for r in range(0, cfg.seq_len + 1 - 2 * g, 4 * g):
            base[:, r + g: r + 2 * g] = base[:, r: r + g]
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        return tokens, labels

    def __next__(self) -> tuple[np.ndarray, np.ndarray]:
        out = self.batch_at(self.step)
        self.step += 1
        return out

    def __iter__(self) -> Iterator[tuple[np.ndarray, np.ndarray]]:
        return self


def tsp_batch_stream(n: int, batch: int, seed: int = 0
                     ) -> Iterator[np.ndarray]:
    """Stream of random TSP coordinate batches (ACO serving workload)."""
    i = 0
    while True:
        rng = np.random.RandomState(seed * 7919 + i)
        yield rng.uniform(0, 1000.0, size=(batch, n, 2))
        i += 1
