from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from .compression import (CompressionState, compress_grads, compression_init,
                          decompress_grads, dequantize_int8, quantize_int8)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "compress_grads", "decompress_grads", "CompressionState",
           "compression_init", "quantize_int8", "dequantize_int8"]
