from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from .compression import compress_grads, decompress_grads, CompressionState

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "compress_grads", "decompress_grads", "CompressionState"]
