"""Gradient compression for the DP all-reduce (distributed-optimization
substrate, DESIGN.md §4).

int8 stochastic-rounding quantisation with per-tensor scales and error
feedback (the quantisation residual is carried and added to the next step's
gradient, preserving convergence). The same hook compresses the ACO deposit
all-reduce — the deposit matrix is gradient-shaped (see islands.py).

Under jit+sharding the quantised tensors are what crosses the DP axis; with
8-bit payloads the all-reduce bytes drop 4x vs f32 (2x vs bf16).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any


class CompressionState(NamedTuple):
    error: PyTree          # error-feedback residuals (f32)


def compression_init(params: PyTree) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params))


def quantize_int8(x: jax.Array, key: Optional[jax.Array] = None,
                  axis: Optional[int] = None
                  ) -> tuple[jax.Array, jax.Array]:
    """int8 quantisation with symmetric scales.

    ``axis=None`` gives the original per-tensor scalar scale (the gradient
    all-reduce path); an integer axis gives one scale per slice along that
    axis (kept as a size-1 dim, so ``q * scale`` broadcasts back) — the
    per-row granularity the quantised pheromone store needs (core/quant.py):
    MMAS rows saturate at very different tau levels, and a per-tensor scale
    would crush cold rows to zero.

    ``key`` switches round-to-nearest to stochastic rounding
    (``floor(y + uniform)``): unbiased in expectation, so values below half
    a quantisation step survive on average instead of deterministically
    rounding to 0 — the property the error-feedback/ACO-exploration
    machinery relies on.
    """
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    y = x / scale
    if key is not None:                       # stochastic rounding
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    q = jnp.clip(y, -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# backwards-compatible private alias (original per-tensor signature)
def _quantize(x: jax.Array, key: Optional[jax.Array]) -> tuple[jax.Array, jax.Array]:
    return quantize_int8(x, key)


def compress_grads(grads: PyTree, state: Optional[CompressionState],
                   key: Optional[jax.Array] = None
                   ) -> tuple[PyTree, PyTree, CompressionState]:
    """-> (quantised int8 pytree, scales pytree, new error state)."""
    if state is None:
        state = compression_init(grads)
    leaves, treedef = jax.tree.flatten(grads)
    errs = jax.tree.leaves(state.error)
    qs, scales, new_errs = [], [], []
    for i, (g, e) in enumerate(zip(leaves, errs)):
        gf = g.astype(jnp.float32) + e
        k = None if key is None else jax.random.fold_in(key, i)
        q, s = _quantize(gf, k)
        deq = q.astype(jnp.float32) * s
        qs.append(q)
        scales.append(s)
        new_errs.append(gf - deq)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, scales),
            CompressionState(jax.tree.unflatten(treedef, new_errs)))


def decompress_grads(q: PyTree, scales: PyTree, dtype=jnp.float32) -> PyTree:
    return jax.tree.map(
        lambda qq, ss: (qq.astype(jnp.float32) * ss).astype(dtype), q, scales)
