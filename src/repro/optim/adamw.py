"""AdamW with cosine schedule and global-norm clipping.

Moments are kept in float32 regardless of (bf16) parameter dtype; with the
FSDP param specs from models/sharding.py the moments inherit the same
sharding, giving ZeRO semantics without extra machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    mu: PyTree
    nu: PyTree
    step: jax.Array


def adamw_init(params: PyTree) -> AdamWState:
    f32 = lambda x: jnp.zeros(x.shape, jnp.float32)
    return AdamWState(mu=jax.tree.map(f32, params),
                      nu=jax.tree.map(f32, params),
                      step=jnp.zeros((), jnp.int32))


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * frac


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads: PyTree, state: AdamWState,
                 params: PyTree) -> tuple[PyTree, AdamWState, dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step)
        vhat = v / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                     # decoupled decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_p = jax.tree.leaves(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(new_m, new_v, step), metrics
