"""While-aware HLO accounting for the roofline analysis.

XLA's HloCostAnalysis (and naive text grepping) counts the body of a
``while`` loop ONCE, but scan-over-layers / scan-over-chunks bodies execute
``trip_count`` times — for a 61-layer model that is a 61x undercount of both
FLOPs and collective bytes. This module parses the post-SPMD HLO text into
its computations, walks the call graph from ENTRY, multiplies every
enclosing while's trip count (recovered from the loop-condition constant),
and accumulates:

  - dot_flops:        2 * prod(output dims) * prod(contracting dims)
  - collective bytes: output bytes of all-reduce / all-gather /
                      reduce-scatter / all-to-all / collective-permute
  - per-collective-op breakdown (for the §Perf iteration log)

Elementwise/transcendental FLOPs are intentionally excluded (MXU roofline
counts matmul work; VPU work is folded into the memory term).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Computation:
    name: str
    dot_flops: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_count: int = 0
    calls: list = field(default_factory=list)       # (kind, names)
    text_lines: list = field(default_factory=list)


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*{")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_ARGS_RE = re.compile(r"%([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*(\w+\[[0-9,]*\])")


def parse_modules(text: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    sym: dict[str, list[int]] = {}
    for line in text.splitlines():
        s = line.strip()
        if "{" in s and "->" in s and not s.startswith("//"):
            hdr = _COMP_HDR.match(s)
            if hdr:
                cur = Computation(hdr.group(2))
                comps[cur.name] = cur
                sym = {}
                for pname, pshape in _PARAM_RE.findall(hdr.group(3)):
                    sym[pname] = _first_shape_dims(pshape)
                if hdr.group(1):
                    entry = cur.name
                continue
        if cur is None:
            continue
        if s == "}":
            cur = None
            continue
        cur.text_lines.append(s)
        m = _OP_RE.match(s)
        if not m:
            continue
        out_name, out_shape_txt, opname = m.groups()
        sym[out_name] = _first_shape_dims(out_shape_txt)
        if opname == "while":
            wm = _WHILE_RE.search(s)
            if wm:
                cur.calls.append(("while", (wm.group(1), wm.group(2))))
        elif opname in ("fusion", "call", "reduce", "map", "scatter",
                        "reduce-window", "sort", "select-and-scatter"):
            cm = _CALLS_RE.search(s)
            if cm:
                cur.calls.append(("call", (cm.group(1),)))
        elif opname == "conditional":
            bm = _BRANCHES_RE.search(s)
            if bm:
                names = [n.strip().lstrip("%") for n in
                         bm.group(1).split(",")]
                cur.calls.append(("cond", tuple(names)))
        if opname == "dot":
            # operands carry no inline types post-optimisation; resolve the
            # lhs shape through the computation's symbol table.
            paren = s[s.index("dot(") + 4:]
            arg_m = _ARGS_RE.search(paren)
            lc = _LHS_C_RE.search(s)
            out_dims = _first_shape_dims(out_shape_txt)
            flops = 0.0
            if arg_m and lc is not None:
                lhs_dims = sym.get(arg_m.group(1), [])
                cdims = [int(x) for x in lc.group(1).split(",") if x != ""]
                k = 1
                for ci in cdims:
                    if ci < len(lhs_dims):
                        k *= lhs_dims[ci]
                n_out = 1
                for d in out_dims:
                    n_out *= d
                flops = 2.0 * n_out * k
            cur.dot_flops += flops
        else:
            for c in COLLECTIVES:
                if opname == c or opname.startswith(c + "-"):
                    b = _shape_bytes(out_shape_txt)
                    cur.coll_bytes[c] = cur.coll_bytes.get(c, 0) + b
                    cur.coll_count += 1
                    break
    return comps, entry


_CONST_RE = re.compile(r"constant\((\d+)\)")


def trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition (scan bound)."""
    best = 1
    for ln in cond.text_lines:
        if "constant(" in ln and ("s32" in ln or "s64" in ln or "u32" in ln):
            for m in _CONST_RE.finditer(ln):
                best = max(best, int(m.group(1)))
    return best


def accumulate(text: str) -> dict:
    comps, entry = parse_modules(text)
    if entry is None:
        return {"dot_flops": 0.0, "collective_bytes": {},
                "collective_total": 0, "collective_count": 0}
    totals = {"dot_flops": 0.0, "coll": {}, "count": 0.0}

    def walk(name: str, mult: float, seen: tuple):
        comp = comps.get(name)
        if comp is None or name in seen:
            return
        totals["dot_flops"] += mult * comp.dot_flops
        for c, b in comp.coll_bytes.items():
            totals["coll"][c] = totals["coll"].get(c, 0.0) + mult * b
        totals["count"] += mult * comp.coll_count
        for kind, names in comp.calls:
            if kind == "while":
                cond_name, body_name = names
                tc = trip_count(comps[cond_name]) if cond_name in comps else 1
                walk(body_name, mult * tc, seen + (name,))
                walk(cond_name, mult * tc, seen + (name,))
            elif kind == "call":
                walk(names[0], mult, seen + (name,))
            elif kind == "cond":
                for nm in names:                     # upper bound: all branches
                    walk(nm, mult, seen + (name,))

    walk(entry, 1.0, ())
    return {
        "dot_flops": totals["dot_flops"],
        "collective_bytes": {k: int(v) for k, v in totals["coll"].items()},
        "collective_total": int(sum(totals["coll"].values())),
        "collective_count": int(totals["count"]),
    }
