"""Beyond-paper example: the paper's ACO engine optimising the framework's
own pipeline-stage placement. Target: deepseek-v3 — its 3 dense-prefix
layers (d_ff 18432) cost ~2.4x a MoE layer's active path, so the standard
uniform contiguous split front-loads stage 0 and bottlenecks the pipeline.

    PYTHONPATH=src python examples/aco_placement.py
"""
import numpy as np

from repro import configs
from repro.core import placement


def model_problem(arch: str, n_stages: int = 8) -> placement.PlacementProblem:
    cfg = configs.get(arch)
    d = cfg.d_model
    costs, traffic = [], []
    for i, spec in enumerate(cfg.layer_specs()):
        if spec.kind == "mamba":
            c = 2 * d * (2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
                         + cfg.ssm_heads) + 2 * cfg.d_inner * d
        elif cfg.attn_kind == "mla":
            qk = cfg.qk_nope_dim + cfg.qk_rope_dim
            c = 2 * (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * qk
                     + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                     + cfg.kv_lora_rank * cfg.n_heads
                     * (cfg.qk_nope_dim + cfg.v_head_dim)
                     + cfg.n_heads * cfg.v_head_dim * d)
        else:
            c = 2 * d * (cfg.n_heads + 2 * cfg.n_kv) * cfg.d_head \
                + 2 * cfg.n_heads * cfg.d_head * d
        if spec.moe:
            active = cfg.top_k + cfg.n_shared_experts
            c += 3 * 2 * d * cfg.ff_expert * active
        elif cfg.d_ff:
            ff = cfg.ff_dense if i < len(cfg.prefix) else cfg.d_ff
            c += 3 * 2 * d * ff
        costs.append(c)
        traffic.append(2 * d)          # bf16 activations per token
    return placement.PlacementProblem(
        layer_costs=tuple(np.asarray(costs, np.float64) / 1e6),
        edge_traffic=tuple(np.asarray(traffic, np.float64) / 1e3),
        n_stages=n_stages)


def _report(tag: str, prob: placement.PlacementProblem) -> None:
    uni_assign, uni_cost = placement.uniform_baseline(prob)
    aco_assign, aco_cost = placement.solve(
        prob, placement.PlacementConfig(ants=64, iterations=120, seed=1))
    print(f"\n[{tag}] layers={prob.n_layers} stages={prob.n_stages}")
    print(f"  uniform contiguous split cost: {uni_cost:.1f}")
    print(f"  ACO placement cost:            {aco_cost:.1f} "
          f"({100 * (1 - aco_cost / uni_cost):+.1f}%)")
    for name, assign in (("ACO", aco_assign), ("uniform", uni_assign)):
        loads = np.zeros(prob.n_stages)
        for i, s in enumerate(assign):
            loads[s] += prob.layer_costs[i]
        print(f"  {name:8s} max-load={loads.max():.0f} "
              f"imbalance={loads.max()/loads.mean():.3f}")


def main() -> None:
    # Production config: dsv3's dense d_ff (18432) = 9 x expert d_ff (2048)
    # exactly, so layer costs are homogeneous and the uniform split is
    # already near-optimal — ACO should MATCH it (honest parity check).
    _report("deepseek-v3 / 8 stages", model_problem("deepseek_v3_671b", 8))

    # Heterogeneous stack (e.g. pruned/early-exit models): a contiguous
    # uniform-count split is poor; the ACO engine finds balanced placements.
    rng = np.random.RandomState(0)
    costs = np.exp(rng.normal(0, 0.9, size=48)) * 100.0
    prob = placement.PlacementProblem(
        layer_costs=tuple(costs), edge_traffic=(2.0,) * 48,
        n_stages=8, comm_lambda=0.05)
    _report("heterogeneous-48 / 8 stages", prob)


if __name__ == "__main__":
    main()


if __name__ == "__main__":
    main()
