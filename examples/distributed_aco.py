"""Distributed ACO: the island model over the `data` mesh axis plus the
city-sharded colony over the `model` axis (the paper's tiling scheme lifted
to the network level — DESIGN.md §4).

Runs on 8 simulated devices:
    PYTHONPATH=src python examples/distributed_aco.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time                                    # noqa: E402

import jax                                     # noqa: E402
import numpy as np                             # noqa: E402

from repro import checkpoint as ck             # noqa: E402
from repro.core import aco, islands, tsp       # noqa: E402


def main() -> None:
    print("devices:", len(jax.devices()))
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    # ---- island model: 4 independent colonies, ring migration + mixing
    inst = tsp.circle_instance(64, seed=3)
    icfg = islands.IslandConfig(
        aco=aco.ACOConfig(selection="gumbel"),
        exchange_every=6, rounds=4, mix_lambda=0.15)
    t0 = time.time()
    st = islands.run_islands(inst, icfg, mesh, island_axes=("data",))
    tour, best = islands.global_best(st)
    print(f"[islands x4] best={best:.1f} optimum={inst.known_optimum:.1f} "
          f"gap={100*(best/inst.known_optimum-1):.2f}% "
          f"({time.time()-t0:.1f}s)")
    assert tsp.is_valid_tour(tour)

    # checkpoint + elastic restart with a different island count
    ckdir = "/tmp/aco_islands_ck"
    mgr = ck.CheckpointManager(ckdir, keep=2, async_write=False)
    mgr.save(0, st)
    restored, _ = mgr.restore(st)
    grown = ck.reshard_islands(restored, 6)
    print(f"[elastic] 4 islands -> {grown.tau.shape[0]} islands "
          f"(checkpoint round-trip)")

    # ---- city-sharded colony: pheromone matrix columns split over `model`
    inst2 = tsp.circle_instance(128, seed=5)
    cfg2 = aco.ACOConfig(iterations=40)
    t0 = time.time()
    st2 = islands.run_sharded_colony(inst2, cfg2, mesh, axis="model")
    gap2 = 100 * (float(st2.best_len) / inst2.known_optimum - 1)
    print(f"[city-sharded] n=128 best={float(st2.best_len):.1f} "
          f"gap={gap2:.2f}% ({time.time()-t0:.1f}s)")
    assert tsp.is_valid_tour(np.asarray(st2.best_tour))


if __name__ == "__main__":
    main()
