"""End-to-end driver: train a ~100M-param OLMo-family model for a few
hundred steps on the synthetic resumable pipeline, with checkpointing.

Full run (~100M params, CPU, slow — a few hours):
    PYTHONPATH=src python examples/train_lm.py --steps 300

Quick demo (reduced ~1M params, ~1 min):
    PYTHONPATH=src python examples/train_lm.py --quick
"""
import argparse
import dataclasses

from repro import configs
from repro.launch.train import train
from repro.models.config import LayerSpec, ModelConfig

# ~100M-param member of the olmo family (non-parametric LN, swiglu, tied).
OLMO_100M = ModelConfig(
    name="olmo-100m",
    n_layers=8,
    d_model=768,
    n_heads=12,
    n_kv=12,
    d_head=64,
    d_ff=3072,
    vocab=50304,
    period=(LayerSpec(),),
    norm="nonparam_ln",
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ck")
    args = ap.parse_args()

    if args.quick:
        out = train("olmo_1b", steps=60, batch=8, seq=128, reduced=True,
                    ckpt_dir=args.ckpt_dir, ckpt_every=20, lr=3e-3,
                    log_every=10)
    else:
        # register the 100M config under a temporary name
        import repro.configs as C
        import types
        mod = types.ModuleType("repro.configs.olmo_100m")
        mod.CONFIG = OLMO_100M
        mod.REDUCED = OLMO_100M
        import sys
        sys.modules["repro.configs.olmo_100m"] = mod
        C.ARCHS = tuple(C.ARCHS) + ("olmo_100m",)
        out = train("olmo_100m", steps=args.steps, batch=8, seq=256,
                    reduced=False, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                    lr=1e-3, log_every=10)
    print("final loss:", out["final_loss"])


if __name__ == "__main__":
    main()
