"""Quickstart: solve a TSP instance with the GPU-paper's data-parallel Ant
System on JAX, validate tour quality against the known optimum, and compare
the strategy ladder from the paper.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import numpy as np

from repro.core import aco, tsp
from repro.solver import SolverService, StreamingSolverService, data_mesh


def main() -> None:
    # A 100-city instance with known optimum (cities on a circle).
    inst = tsp.circle_instance(100, seed=7)
    print(f"instance: {inst.name}  n={inst.n}  optimum={inst.known_optimum:.1f}")

    # Paper-faithful configuration: m = n ants, alpha=1, beta=2, rho=0.5,
    # data-parallel construction with I-Roulette selection (paper Fig. 1).
    cfg = aco.ACOConfig(iterations=80, construction="data_parallel",
                        selection="iroulette", deposit="scatter")
    t0 = time.time()
    state = aco.run(inst, cfg)
    dt = time.time() - t0
    gap = 100 * (float(state.best_len) / inst.known_optimum - 1)
    print(f"[data-parallel AS]  best={float(state.best_len):.1f} "
          f"gap={gap:.2f}%  ({dt:.1f}s, {cfg.iterations} iters)")
    assert tsp.is_valid_tour(np.asarray(state.best_tour))

    # Same engine on the kernel route: construction runs the fused
    # choice->select kernel (row gather + tau^a*eta^b + masking + selection
    # in one pass, no (n, n) choice precompute) and the deposit runs the
    # one-hot-matmul pheromone kernel.  Constructed tours are bitwise the
    # data-parallel route's (DESIGN.md §10).
    cfg_k = aco.ACOConfig(iterations=80, use_pallas=True)
    state_k = aco.run(inst, cfg_k)
    gap_k = 100 * (float(state_k.best_len) / inst.known_optimum - 1)
    print(f"[pallas kernels]    best={float(state_k.best_len):.1f} gap={gap_k:.2f}%")

    # NN-list variant (paper §II): restricted candidate lists.
    cfg_nn = aco.ACOConfig(iterations=80, construction="nn_list", nn_k=20)
    state_nn = aco.run(inst, cfg_nn)
    gap_nn = 100 * (float(state_nn.best_len) / inst.known_optimum - 1)
    print(f"[nn-list AS]        best={float(state_nn.best_len):.1f} gap={gap_nn:.2f}%")

    # MMAS variant (beyond paper).
    cfg_mm = aco.ACOConfig(iterations=80, variant="mmas", selection="gumbel")
    state_mm = aco.run(inst, cfg_mm)
    gap_mm = 100 * (float(state_mm.best_len) / inst.known_optimum - 1)
    print(f"[MMAS]              best={float(state_mm.best_len):.1f} gap={gap_mm:.2f}%")

    # MMAS + batched local search (DESIGN.md §7): the iteration-best tour is
    # polished by NN-restricted 2-opt before it deposits, entirely on-device.
    cfg_ls = aco.ACOConfig(iterations=80, variant="mmas", selection="gumbel",
                           local_search="2opt", ls_tours="iteration_best",
                           ls_rounds=64)
    state_ls = aco.run(inst, cfg_ls)
    gap_ls = 100 * (float(state_ls.best_len) / inst.known_optimum - 1)
    print(f"[MMAS + 2-opt]      best={float(state_ls.best_len):.1f} gap={gap_ls:.2f}%")
    assert tsp.is_valid_tour(np.asarray(state_ls.best_tour))

    # Batched multi-instance solving (DESIGN.md §8): heterogeneous instances
    # are padded to a power-of-two bucket and one vmapped program advances
    # all colonies together — the service buckets, batches and reports
    # throughput.  Each instance's result is exactly what it would get
    # solved alone with the same seed (batch composition never leaks).
    svc = SolverService(aco.ACOConfig(iterations=40, selection="gumbel"),
                        max_batch=4)
    for k, n in enumerate((40, 52, 64)):
        svc.submit(tsp.circle_instance(n, seed=k))
    t0 = time.time()
    for r in svc.run():
        print(f"[batched solver]    {r.name}: n={r.n} bucket={r.bucket} "
              f"best={r.best_len:.1f} gap={r.gap_pct:.2f}%")
        assert tsp.is_valid_tour(r.best_tour)
    print(f"[batched solver]    {svc.stats['instances_per_s']:.1f} "
          f"instances/s over {svc.stats['batches']} batch(es) "
          f"({time.time()-t0:.1f}s)")

    # Streaming / continuous batching (DESIGN.md §9): a resident slot pool
    # steps in fixed chunks; finished slots are harvested and refilled
    # mid-run, so requests can arrive while siblings are still solving —
    # and every result is still bitwise what a solo run would return.
    # Mixed per-request hyperparameter profiles share the one compiled
    # program (per-slot alpha/beta/rho/q operands).
    stream = StreamingSolverService(
        aco.ACOConfig(iterations=40, selection="gumbel"), max_batch=2,
        chunk=5, per_instance_hyper=True)
    stream.submit(tsp.circle_instance(40, seed=0), seed=0)
    stream.submit(tsp.circle_instance(52, seed=1), seed=1,
                  hyper={"alpha": 2.0, "rho": 0.3})   # its own profile
    stream.step()                                      # pool is now running
    stream.submit(tsp.circle_instance(44, seed=2), seed=2,
                  priority=5)                          # admitted mid-run
    t0 = time.time()
    for r in stream.run_until_drained():
        print(f"[streaming solver]  {r.name}: n={r.n} best={r.best_len:.1f} "
              f"gap={r.gap_pct:.2f}% latency={r.latency_s:.2f}s")
        assert tsp.is_valid_tour(r.best_tour)
    s = stream.stats
    print(f"[streaming solver]  occupancy={s['occupancy_mean']:.2f} "
          f"fills={s['fills']} chunks={s['chunks']} "
          f"({time.time()-t0:.1f}s)")

    # Sharded solver fabric (DESIGN.md §11): the same services spread
    # their work over a device mesh — batch jobs shard the instance axis
    # (uneven batches are phantom-padded), streaming runs one resident
    # pool per device — and every result stays bitwise identical to the
    # single-device run.  On this host the mesh covers whatever devices
    # exist (run under XLA_FLAGS=--xla_force_host_platform_device_count=8
    # to see D=8); on a TPU pod slice it covers the slice.
    mesh = data_mesh()
    sharded = SolverService(aco.ACOConfig(iterations=40, selection="gumbel"),
                            max_batch=4, mesh=mesh)
    for k, n in enumerate((40, 52, 64)):
        sharded.submit(tsp.circle_instance(n, seed=k))
    for r in sharded.run():
        print(f"[sharded solver]    {r.name}: n={r.n} best={r.best_len:.1f} "
              f"gap={r.gap_pct:.2f}%")
        assert tsp.is_valid_tour(r.best_tour)
    print(f"[sharded solver]    {sharded.stats['devices']} device(s), "
          f"{sharded.stats['instances_per_s']:.1f} instances/s")

    # Sparse/paged representation (DESIGN.md §12): pheromone, distance and
    # eta live only on (n, k) candidate pages — no (n, n) tensor, so
    # paper-scale instances (pr1002/pr2392 and beyond) fit. With k = n-1
    # the sparse trajectory is bitwise the dense one; with small k it is
    # usually *better* at equal budgets (candidate pruning).  Partial-ACO
    # construction mutates a bounded window of the running best instead of
    # rebuilding whole tours: O(m·w·k) per iteration.
    from repro.sparse import store
    inst_big = tsp.random_instance(512, seed=3)
    cfg_sp = aco.ACOConfig(iterations=20, variant="mmas", sparse=True,
                           sparse_k=16, m=64)
    state_sp = aco.run(inst_big, cfg_sp)       # cfg.sparse routes here
    prob = store.make_sparse_problem(inst_big, 16)
    print(f"[sparse MMAS]       n={inst_big.n} k=16 "
          f"best={float(state_sp.best_len):.1f} resident="
          f"{store.resident_bytes(prob, state_sp) / 1e6:.2f}MB "
          f"(dense would hold "
          f"{store.dense_resident_bytes(inst_big.n) / 1e6:.1f}MB)")
    assert tsp.is_valid_tour(np.asarray(state_sp.best_tour))
    cfg_pa = aco.ACOConfig(iterations=40, variant="mmas", sparse=True,
                           sparse_k=16, m=64, construction="partial",
                           partial_window=48)
    state_pa = aco.run(inst_big, cfg_pa)
    print(f"[sparse Partial]    window=48 "
          f"best={float(state_pa.best_len):.1f} (monotone from the NN tour)")
    assert tsp.is_valid_tour(np.asarray(state_pa.best_tour))


if __name__ == "__main__":
    main()
